"""Sharded checkpointing: per-leaf .npy blobs + msgpack manifest.

Restart-safe (atomic rename of the step directory), reshard-on-restore
(restore is just `jax.device_put(value, sharding)` — any mesh, any layout,
which is what the elastic-remap path needs after a device failure), and
self-describing (tree structure serialized path-wise, dtypes preserved,
bf16 stored via uint16 view).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((name, leaf))
    return out


def _np_save(path: str, arr) -> Dict[str, str]:
    arr = np.asarray(jax.device_get(arr))
    meta = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    if arr.dtype == jnp.bfloat16:
        np.save(path, arr.view(np.uint16))
        meta["dtype"] = "bfloat16"
    else:
        np.save(path, arr)
    return meta


def _np_load(path: str, meta: Dict) -> np.ndarray:
    arr = np.load(path)
    if meta["dtype"] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def save_checkpoint(ckpt_dir: str, state, step: int) -> str:
    """Atomic: writes into <dir>/tmp-<step>, renames to <dir>/step-<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _flatten(state):
        fn = name.replace("/", "__") + ".npy"
        manifest["leaves"][name] = {
            "file": fn, **_np_save(os.path.join(tmp, fn), leaf)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree or eval_shape of
    one).  With ``shardings`` (same tree of NamedSharding), leaves go
    straight to their (possibly brand-new, post-remap) devices.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step-{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _flatten(like)]
    leaves = []
    flat_sh = [s for _, s in _flatten(shardings)] if shardings is not None \
        else [None] * len(names)
    for name, sh in zip(names, flat_sh):
        meta = manifest["leaves"][name]
        arr = _np_load(os.path.join(d, meta["file"]), meta)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
