"""Serving: the real batched engine and the request-level serving plane.

Two halves share this package:

* :mod:`repro.serve.engine` — :class:`ServeEngine`, the *runnable* batched
  prefill/decode loop over a JAX ModelBundle (CPU-testable; imports jax);
* the **serving plane** (:mod:`~repro.serve.requests`,
  :mod:`~repro.serve.kv`, :mod:`~repro.serve.plane`) — the analytic
  request-level simulation the cluster scheduler drives: per-model request
  streams, KV-cache occupancy over a real buddy arena, and continuous
  batching at phase-aware (prefill/decode) rates.  These modules are
  jax-free; ``tests/test_serving.py`` cross-checks the analytic decode
  rate against a real ``ServeEngine`` run.

``ServeEngine`` and friends are imported lazily so that scheduler runs and
benchmarks using only the plane never pay the jax import.
"""
from .kv import KVStats, TenantKV
from .plane import (PressureSignals, RequestRecord, ServingPlane,
                    TenantServer)
from .requests import (RequestClass, RequestSpec, SERVE_PROFILES,
                       ServeProfile, get_profile, sample_requests)

_ENGINE_EXPORTS = ("ServeEngine", "EngineConfig", "Request",
                   "seed_decode_cache")

__all__ = [
    "KVStats", "TenantKV",
    "PressureSignals", "RequestRecord", "ServingPlane", "TenantServer",
    "RequestClass", "RequestSpec", "SERVE_PROFILES", "ServeProfile",
    "get_profile", "sample_requests",
    *_ENGINE_EXPORTS,
]


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
