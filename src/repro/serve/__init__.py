from .engine import ServeEngine, EngineConfig, Request, seed_decode_cache
