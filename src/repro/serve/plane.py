"""The ServingPlane: request-level continuous batching per resident tenant.

Sits between the cluster scheduler's event loop and the analytic
simulator: each resident LLM tenant gets a :class:`TenantServer` that
replays its (deterministic, per-tenant-seeded) request stream through a
continuous-batching loop —

* **prefill** passes admit pending requests into free batch slots (KV
  blocks permitting — admission charges the *real*
  :class:`~repro.core.buddy.BuddyAllocator` arena via
  :class:`~repro.serve.kv.TenantKV`) and produce each request's first
  token; while a prefill is in flight decode pauses, which is exactly the
  TTFT-vs-TPOT interference phase-aware schedulers exploit;
* **decode** advances every active slot one token per step at the
  bandwidth-bound step time of the tenant's current
  :class:`~repro.core.simulator.PhaseModel` (weights streaming when the
  shards don't fit in aggregate scratchpad, live KV bytes, RTT-walk
  stalls, contention-scaled all-reduce); KV growth past a block boundary
  can hit real OOM, preempting the youngest request vLLM-style
  (free-and-recompute);
* the math is segment-analytic, not token-discrete: between scheduler
  events the server advances in closed form to the next boundary (request
  arrival, prefill completion, earliest slot completion, window end), so
  cost is O(requests x segments), independent of token counts.

The scheduler drives one :class:`ServingPlane` per run (`attach` on
admission, `advance` from its time-integration hook, `pressure` for the
elastic-resize signals, `detach` on departure) and folds the per-request
TTFT/TPOT/goodput records into :class:`~repro.sched.cluster.ClusterMetrics`.
Everything is deterministic for a given (trace seed, tenant id).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional

from ..core.simulator import PhaseModel
from .kv import TenantKV
from .requests import (RequestSpec, ServeProfile, get_profile,
                       sample_requests)

_EPS = 1e-12


@dataclasses.dataclass
class RequestRecord:
    """One request's measured life (times absolute seconds; a request the
    tenant departed on keeps ``done_s=None`` and counts as incomplete)."""
    tid: int
    rid: int
    cls: str
    arrival_s: float
    prompt_tokens: int
    target_tokens: int
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    tokens_out: int = 0
    preempts: int = 0

    @property
    def completed(self) -> bool:
        return self.done_s is not None

    @property
    def ttft_s(self) -> float:
        """Time to first token (inf when the request never prefilled)."""
        if self.first_token_s is None:
            return math.inf
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        """Mean time per output token after the first (0 for 1-token
        requests; inf when incomplete)."""
        if not self.completed:
            return math.inf
        if self.target_tokens <= 1:
            return 0.0
        return (self.done_s - self.first_token_s) / (self.target_tokens - 1)

    def sla_good(self, ttft_slo_s: float, tpot_slo_s: float) -> bool:
        """Did this request meet both latency targets?"""
        return (self.completed and self.ttft_s <= ttft_slo_s
                and self.tpot_s <= tpot_slo_s)


@dataclasses.dataclass
class PressureSignals:
    """What the scheduler's resize controller reads each epoch."""
    queue_depth: int              # requests waiting for a batch slot
    kv_occupancy: float           # fraction of the KV arena in use
    batch_fill: float             # active slots / max_batch
    kv_blocked: bool              # an admission was deferred on KV OOM


@dataclasses.dataclass
class _Pending:
    spec: RequestSpec
    arrival_s: float
    preempts: int = 0


@dataclasses.dataclass
class _Active:
    rec: RequestRecord
    spec: RequestSpec
    ctx_tokens: float             # prompt + produced (fractional mid-segment)
    produced: float               # output tokens produced so far


@dataclasses.dataclass
class _Prefill:
    entries: List[_Pending]
    tokens_left: float


class TenantServer:
    """Continuous batching for one resident tenant (see module docstring)."""

    def __init__(self, tid: int, profile: ServeProfile,
                 stream: List[RequestSpec], arrival_s: float,
                 admit_s: float, depart_s: float):
        self.tid = tid
        self.profile = profile
        self.kv = TenantKV(profile.kv_arena_bytes, profile.kv_block_bytes,
                           profile.kv_bytes_per_token)
        # requests arrive relative to the tenant's *arrival*, not its
        # admission: anything that arrived while the tenant waited in the
        # cluster queue is backlogged at admit, and its TTFT includes the
        # admission wait — queueing latency is request latency
        self.arrival_s = arrival_s
        self.admit_s = admit_s
        self.depart_s = depart_s
        self._stream = stream
        self._next = 0
        self.t = admit_s
        self.pending: Deque[_Pending] = deque()
        self.prefill: Optional[_Prefill] = None
        self.active: List[_Active] = []
        self.records: List[RequestRecord] = []
        self.kv_blocked = False
        self.n_dropped = 0            # requests bigger than the whole arena

    # -- arrival stream ------------------------------------------------------
    def _peek_arrival(self) -> Optional[float]:
        if self._next >= len(self._stream):
            return None
        return self.arrival_s + self._stream[self._next].t_s

    def _ingest(self, t: float) -> None:
        while self._next < len(self._stream) and \
                self.arrival_s + self._stream[self._next].t_s <= t + _EPS:
            spec = self._stream[self._next]
            self.pending.append(_Pending(
                spec=spec, arrival_s=self.arrival_s + spec.t_s))
            self._next += 1

    # -- bookkeeping ---------------------------------------------------------
    def _make_record(self, e: _Pending) -> RequestRecord:
        return RequestRecord(
            tid=self.tid, rid=e.spec.rid, cls=e.spec.cls,
            arrival_s=e.arrival_s, prompt_tokens=e.spec.prompt_tokens,
            target_tokens=e.spec.max_new_tokens, preempts=e.preempts)

    def _censor(self, e: _Pending) -> None:
        """Record a request that will never be served (dropped, or in
        flight / still queued at tenant departure) unless it already has a
        record from an earlier activation."""
        if not any(r.rid == e.spec.rid for r in self.records):
            self.records.append(self._make_record(e))

    def _activate(self, e: _Pending, first_token_s: float) -> None:
        rec = self._make_record(e)
        if e.preempts:
            # a preempted request keeps its original record (first token
            # already served once; recompute regenerates the rest)
            for r in self.records:
                if r.rid == e.spec.rid:
                    rec = r
                    rec.preempts = e.preempts
                    break
            else:
                self.records.append(rec)
        else:
            self.records.append(rec)
        if rec.first_token_s is None:
            rec.first_token_s = first_token_s
        self.active.append(_Active(rec=rec, spec=e.spec,
                                   ctx_tokens=float(e.spec.prompt_tokens + 1),
                                   produced=1.0))

    def _finalize(self, a: _Active, t: float) -> None:
        a.rec.done_s = t
        a.rec.tokens_out = a.spec.max_new_tokens
        self.kv.release(a.spec.rid)
        self.kv_blocked = False

    def _preempt_youngest(self) -> bool:
        """KV grow OOM: evict the youngest active request (latest arrival,
        highest rid tiebreak) for free-and-recompute re-admission."""
        if not self.active:
            return False
        victim = max(self.active,
                     key=lambda a: (a.rec.arrival_s, a.spec.rid))
        self.active.remove(victim)
        self.kv.release(victim.spec.rid)
        self.kv_blocked = False
        self.pending.appendleft(_Pending(
            spec=victim.spec, arrival_s=victim.rec.arrival_s,
            preempts=victim.rec.preempts + 1))
        victim.rec.preempts += 1
        return True

    def _admit_pending(self) -> List[_Pending]:
        """Move pending requests into a prefill batch while slots and KV
        blocks last.  A request that cannot fit in an *empty* arena is
        dropped (it could never be served)."""
        batch: List[_Pending] = []
        while self.pending and \
                len(self.active) + len(batch) < self.profile.max_batch:
            e = self.pending[0]
            # a request whose *full* context (prompt + every output
            # token) can never fit the arena is unserveable: admitting it
            # would loop admit -> grow-OOM -> self-preempt forever
            if not self.kv.fits_arena(e.spec.prompt_tokens
                                      + e.spec.max_new_tokens):
                self.pending.popleft()
                self._censor(e)
                self.n_dropped += 1
                continue
            if self.kv.try_admit(e.spec.rid, e.spec.prompt_tokens + 1):
                self.pending.popleft()
                batch.append(e)
                continue
            self.kv_blocked = True
            break
        return batch

    # -- the micro event loop ------------------------------------------------
    def advance(self, t0: float, t1: float, phase: PhaseModel) -> None:
        """Advance the server through the active window ``[t0, t1)`` under
        the given phase rates (constant within a scheduler window)."""
        t = max(self.t, t0)
        if t1 <= t + _EPS:
            self.t = max(self.t, t1)
            return
        max_iters = 1000 + 50 * len(self._stream)
        iters = 0
        while t < t1 - _EPS:
            iters += 1
            if iters > max_iters:
                raise RuntimeError(
                    f"TenantServer {self.tid}: micro loop did not converge "
                    f"(t={t}, window=({t0}, {t1}))")
            self._ingest(t)
            # start a prefill pass when slots and requests are available
            if self.prefill is None:
                batch = self._admit_pending()
                if batch:
                    self.prefill = _Prefill(
                        entries=batch,
                        tokens_left=float(sum(e.spec.prompt_tokens
                                              for e in batch)))
            if self.prefill is not None:
                need_s = self.prefill.tokens_left / phase.prefill_tokens_per_s
                if t + need_s <= t1:
                    t += need_s
                    for e in self.prefill.entries:
                        self._activate(e, first_token_s=t)
                    self.prefill = None
                    continue
                self.prefill.tokens_left -= \
                    (t1 - t) * phase.prefill_tokens_per_s
                t = t1
                break
            if self.active:
                t = self._decode_segment(t, t1, phase)
                continue
            nxt = self._peek_arrival()
            if nxt is None or nxt >= t1:
                t = t1
                break
            t = nxt
        self.t = max(self.t, t1)

    def _decode_segment(self, t: float, t1: float,
                        phase: PhaseModel) -> float:
        """One closed-form decode segment: everybody gains ``dtok`` tokens,
        where the segment ends at the earliest of window end, a request
        arrival that could start a prefill, or the earliest completion."""
        rids = [a.spec.rid for a in self.active]
        kv_bytes = sum(a.ctx_tokens for a in self.active) * \
            self.kv.kv_bytes_per_token
        step_s = max(phase.decode_step_s(kv_bytes,
                                         self.kv.stall_ranges(rids)), 1e-9)
        boundary = t1
        if len(self.active) < self.profile.max_batch:
            nxt = self._peek_arrival()
            if nxt is not None and t < nxt < boundary:
                boundary = nxt
        min_rem = min(a.spec.max_new_tokens - a.produced
                      for a in self.active)
        t_complete = t + min_rem * step_s
        if t_complete <= boundary + _EPS:
            end, dtok = t_complete, min_rem
        else:
            end, dtok = boundary, (boundary - t) / step_s
        # KV growth for this segment's token gain — real buddy allocation,
        # preempting the youngest slot on OOM and re-planning the segment
        preempted = False
        for a in list(self.active):
            if a not in self.active:
                continue                        # preempted by an earlier grow
            need = int(math.ceil(a.ctx_tokens + dtok))
            while not self.kv.try_grow(a.spec.rid, need):
                if not self._preempt_youngest():
                    break
                preempted = True
                if a not in self.active:       # preempted itself
                    break
        if preempted:
            # any eviction stales the plan (step time, min_rem and the
            # boundary were computed with the victim in the batch)
            return t
        for a in self.active:
            a.ctx_tokens += dtok
            a.produced += dtok
        done = [a for a in self.active
                if a.produced >= a.spec.max_new_tokens - 1e-9]
        for a in done:
            self.active.remove(a)
            self._finalize(a, end)
        return end

    # -- scheduler-facing ----------------------------------------------------
    def pressure(self) -> PressureSignals:
        return PressureSignals(
            queue_depth=len(self.pending),
            kv_occupancy=self.kv.occupancy(),
            batch_fill=len(self.active) / max(self.profile.max_batch, 1),
            kv_blocked=self.kv_blocked)

    def finish(self) -> List[RequestRecord]:
        """Tenant departed: censor everything in flight — including stream
        entries never ingested because a pause covered the final window
        (every sampled request must appear in exactly one record, whatever
        the policy's pause pattern) — and release KV."""
        self._ingest(self.depart_s)
        if self.prefill is not None:
            for e in self.prefill.entries:
                self._censor(e)
            self.prefill = None
        for a in self.active:
            a.rec.tokens_out = int(a.produced)
        for e in self.pending:
            self._censor(e)
        self.active = []
        self.pending.clear()
        self.kv.release_all()
        return self.records


class ServingPlane:
    """All resident tenant servers of one scheduler run."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.servers: Dict[int, TenantServer] = {}
        # EWMA of observed prefill rates (tokens/s) across every advance —
        # the scheduler's SLA-aware admission predicts a queued tenant's
        # TTFT at *current* load from this
        self._prefill_rate_ewma = 0.0

    # number of residents streaming from HBM during decode — every
    # attached server shares the port (the phase model's
    # ``decode_hbm_clients``)
    @property
    def n_attached(self) -> int:
        return len(self.servers)

    def request_seed(self, tid: int) -> int:
        return (self.seed * 1_000_003 + tid) & 0x7FFFFFFF

    def attach(self, tid: int, model: str, arrival_s: float, admit_s: float,
               depart_s: float) -> bool:
        """Start serving a newly-admitted tenant.  Returns False (no-op)
        for models without a serving profile (CNN frame tenants).  The
        request stream spans the tenant's service duration but is anchored
        at its cluster *arrival* — requests that arrived during the
        admission wait are backlogged, so queue latency surfaces as TTFT.
        """
        profile = get_profile(model)
        if profile is None:
            return False
        stream = sample_requests(profile, depart_s - admit_s,
                                 self.request_seed(tid))
        self.servers[tid] = TenantServer(tid, profile, stream, arrival_s,
                                         admit_s, depart_s)
        return True

    def is_attached(self, tid: int) -> bool:
        return tid in self.servers

    def advance(self, tid: int, t0: float, t1: float,
                phase: PhaseModel) -> None:
        r = phase.prefill_tokens_per_s
        self._prefill_rate_ewma = r if self._prefill_rate_ewma == 0.0 \
            else 0.9 * self._prefill_rate_ewma + 0.1 * r
        self.servers[tid].advance(t0, t1, phase)

    def predicted_prefill_s(self, profile: ServeProfile) -> float:
        """Predicted TTFT contribution of one mean-sized prompt at the
        currently-observed cluster prefill rate (0 before any window ran):
        what SLA-aware admission subtracts from a queued tenant's
        deadline."""
        if self._prefill_rate_ewma <= 0.0:
            return 0.0
        w = sum(c.weight for c in profile.classes)
        mean_prompt = sum(c.weight * c.prompt_mean
                          for c in profile.classes) / max(w, 1e-9)
        return mean_prompt / self._prefill_rate_ewma

    def pressure(self, tid: int) -> PressureSignals:
        return self.servers[tid].pressure()

    def detach(self, tid: int) -> TenantServer:
        """Tenant departed: finalize its in-flight requests, release the KV
        arena, and return the (finished) server for metrics folding."""
        server = self.servers.pop(tid)
        server.finish()
        return server
