"""The ServingPlane: request-level continuous batching per resident tenant.

Sits between the cluster scheduler's event loop and the analytic
simulator: each resident LLM tenant gets a continuous-batching server that
replays its (deterministic, per-tenant-seeded) request stream —

* **prefill** passes admit pending requests into free batch slots (KV
  blocks permitting — admission charges the *real*
  :class:`~repro.core.buddy.BuddyAllocator` arena via
  :class:`~repro.serve.kv.TenantKV`) and produce each request's first
  token; while a prefill is in flight decode pauses, which is exactly the
  TTFT-vs-TPOT interference phase-aware schedulers exploit;
* **decode** advances every active slot one token per step at the
  bandwidth-bound step time of the tenant's current
  :class:`~repro.core.simulator.PhaseModel` (weights streaming when the
  shards don't fit in aggregate scratchpad, live KV bytes, RTT-walk
  stalls, contention-scaled all-reduce); KV growth past a block boundary
  can hit real OOM, preempting the youngest request vLLM-style
  (free-and-recompute);
* the math is segment-analytic, not token-discrete: between scheduler
  events the server advances in closed form to the next boundary (request
  arrival, prefill completion, earliest slot completion, window end), so
  cost is O(requests x segments), independent of token counts.

Two engines implement the same trajectory:

* ``engine="scalar"`` — :class:`TenantServer`, one Python micro event
  loop per tenant.  The reference semantics; every boundary below is
  defined by this code.
* ``engine="vector"`` (default) — :class:`_VectorPool`, one numpy
  struct-of-arrays over *all* resident tenants.  Each iteration of its
  loop advances every in-window tenant through exactly one scalar-loop
  iteration: the per-segment closed forms (prefill drain, decode step
  time, min-over-boundaries, token gain) are evaluated as array
  expressions whose float64 arithmetic mirrors the scalar path
  operation-for-operation, and only the *boundary events* (ingest,
  admission, activation, completion, KV grow/preempt) fall back to
  per-tenant Python.  Trajectories are bit-identical — the serving-scale
  gate pins ``benchmarks/serving_sim._request_trajectory`` equality on
  the 8x8 gate trace.

With ``record_requests=False`` the plane keeps **no** per-request
objects: completed requests stream through the plane's ``sink`` (exact
counters + P² percentile sketches in
:class:`~repro.sched.cluster.ClusterMetrics`) the moment they finish, and
``detach`` returns only aggregate counts — peak resident memory is
O(active tenants x batch slots), which is what makes million-request
traces feasible.

The scheduler drives one :class:`ServingPlane` per run (`attach` on
admission, `advance_all` from its time-integration hook, `pressure` for
the elastic-resize signals, `detach` on departure) and folds the returned
:class:`ServerFold` into :class:`~repro.sched.cluster.ClusterMetrics`.
Everything is deterministic for a given (trace seed, tenant id).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core.simulator import PhaseModel
from ..obs.trace import Tracer
from .kv import KVStats, TenantKV
from .requests import (ArrivalProcess, RequestSpec, ServeProfile,
                       get_profile, sample_requests)

_EPS = 1e-12

#: hard upper bound on any profile's ``max_batch`` — the vector engine's
#: slot axis is this wide
MAX_BATCH_SLOTS = 8

#: sink signature: (ttft_s, tpot_s, tokens_out, sla_good)
Sink = Callable[[float, float, int, bool], None]


@dataclasses.dataclass
class RequestRecord:
    """One request's measured life (times absolute seconds; a request the
    tenant departed on keeps ``done_s=None`` and counts as incomplete)."""
    tid: int
    rid: int
    cls: str
    arrival_s: float
    prompt_tokens: int
    target_tokens: int
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    tokens_out: int = 0
    preempts: int = 0

    @property
    def completed(self) -> bool:
        return self.done_s is not None

    @property
    def ttft_s(self) -> float:
        """Time to first token (inf when the request never prefilled)."""
        if self.first_token_s is None:
            return math.inf
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        """Mean time per output token after the first (0 for 1-token
        requests; inf when incomplete)."""
        if not self.completed:
            return math.inf
        if self.target_tokens <= 1:
            return 0.0
        return (self.done_s - self.first_token_s) / (self.target_tokens - 1)

    def sla_good(self, ttft_slo_s: float, tpot_slo_s: float) -> bool:
        """Did this request meet both latency targets?"""
        return (self.completed and self.ttft_s <= ttft_slo_s
                and self.tpot_s <= tpot_slo_s)


@dataclasses.dataclass
class PressureSignals:
    """What the scheduler's resize controller reads each epoch."""
    queue_depth: int              # requests waiting for a batch slot
    kv_occupancy: float           # fraction of the KV arena in use
    batch_fill: float             # active slots / max_batch
    kv_blocked: bool              # an admission was deferred on KV OOM


@dataclasses.dataclass
class ServerFold:
    """What ``ServingPlane.detach`` hands the scheduler to fold into the
    metrics.  Completed requests were already streamed through the sink at
    finalize time (both engines, identical order); this carries only what
    remains at departure: the arrival census, censored decode tokens, KV
    telemetry — and, in record mode, the full per-request records for the
    determinism gates' ``request_log``."""
    records: Optional[List[RequestRecord]]   # None when record_requests off
    n_requests: int                          # total sampled requests
    censored_tokens: int                     # tokens by incomplete requests
    kv_stats: KVStats
    n_dropped: int
    # requests still in flight at detach (queued + prefilling + decoding,
    # excluding drops) — what a fault kill loses; identical between the
    # engines: in record mode it is the not-completed records minus the
    # drops, which is exactly the live pending/prefill/active census
    n_incomplete: int = 0


@dataclasses.dataclass
class _Pending:
    spec: RequestSpec
    arrival_s: float
    preempts: int = 0


@dataclasses.dataclass
class _Active:
    rec: RequestRecord
    spec: RequestSpec
    ctx_tokens: float             # prompt + produced (fractional mid-segment)
    produced: float               # output tokens produced so far


@dataclasses.dataclass
class _Prefill:
    entries: List[_Pending]
    tokens_left: float


class TenantServer:
    """Continuous batching for one resident tenant (see module docstring).

    The scalar reference engine: retained verbatim behind
    ``ServingPlane(engine="scalar")`` so the vectorized path can be pinned
    bit-identical against it (same discipline as ``rescore="oracle"``).
    """

    def __init__(self, tid: int, profile: ServeProfile,
                 stream: List[RequestSpec], arrival_s: float,
                 admit_s: float, depart_s: float,
                 sink: Optional[Sink] = None,
                 tracer: Optional["Tracer"] = None):
        self.tid = tid
        self.tracer = tracer if tracer is not None else Tracer.NULL
        self.profile = profile
        self.kv = TenantKV(profile.kv_arena_bytes, profile.kv_block_bytes,
                           profile.kv_bytes_per_token)
        # requests arrive relative to the tenant's *arrival*, not its
        # admission: anything that arrived while the tenant waited in the
        # cluster queue is backlogged at admit, and its TTFT includes the
        # admission wait — queueing latency is request latency
        self.arrival_s = arrival_s
        self.admit_s = admit_s
        self.depart_s = depart_s
        self._stream = stream
        self._next = 0
        self.t = admit_s
        self.pending: Deque[_Pending] = deque()
        self.prefill: Optional[_Prefill] = None
        self.active: List[_Active] = []
        self.records: List[RequestRecord] = []
        self.kv_blocked = False
        self.n_dropped = 0            # requests bigger than the whole arena
        self.sink = sink

    # -- arrival stream ------------------------------------------------------
    def _peek_arrival(self) -> Optional[float]:
        if self._next >= len(self._stream):
            return None
        return self.arrival_s + self._stream[self._next].t_s

    def _ingest(self, t: float) -> None:
        while self._next < len(self._stream) and \
                self.arrival_s + self._stream[self._next].t_s <= t + _EPS:
            spec = self._stream[self._next]
            self.pending.append(_Pending(
                spec=spec, arrival_s=self.arrival_s + spec.t_s))
            self._next += 1

    # -- bookkeeping ---------------------------------------------------------
    def _make_record(self, e: _Pending) -> RequestRecord:
        return RequestRecord(
            tid=self.tid, rid=e.spec.rid, cls=e.spec.cls,
            arrival_s=e.arrival_s, prompt_tokens=e.spec.prompt_tokens,
            target_tokens=e.spec.max_new_tokens, preempts=e.preempts)

    def _censor(self, e: _Pending) -> None:
        """Record a request that will never be served (dropped, or in
        flight / still queued at tenant departure) unless it already has a
        record from an earlier activation."""
        if not any(r.rid == e.spec.rid for r in self.records):
            self.records.append(self._make_record(e))

    def _activate(self, e: _Pending, first_token_s: float) -> None:
        rec = self._make_record(e)
        if e.preempts:
            # a preempted request keeps its original record (first token
            # already served once; recompute regenerates the rest)
            for r in self.records:
                if r.rid == e.spec.rid:
                    rec = r
                    rec.preempts = e.preempts
                    break
            else:
                self.records.append(rec)
        else:
            self.records.append(rec)
        if rec.first_token_s is None:
            rec.first_token_s = first_token_s
        self.active.append(_Active(rec=rec, spec=e.spec,
                                   ctx_tokens=float(e.spec.prompt_tokens + 1),
                                   produced=1.0))

    def _finalize(self, a: _Active, t: float) -> None:
        a.rec.done_s = t
        a.rec.tokens_out = a.spec.max_new_tokens
        self.kv.release(a.spec.rid)
        self.kv_blocked = False
        if self.sink is not None:
            self.sink(a.rec.ttft_s, a.rec.tpot_s, a.rec.tokens_out,
                      a.rec.sla_good(self.profile.ttft_slo_s,
                                     self.profile.tpot_slo_s))
        if self.tracer.enabled:
            rec = a.rec
            ft = rec.first_token_s
            self.tracer.span("prefill", "request", rec.arrival_s,
                             ft - rec.arrival_s, tid=self.tid,
                             args={"rid": rec.rid,
                                   "prompt_tokens": rec.prompt_tokens})
            self.tracer.span("decode", "request", ft, t - ft, tid=self.tid,
                             args={"rid": rec.rid,
                                   "tokens": rec.tokens_out,
                                   "preempts": rec.preempts})

    def _preempt_youngest(self, t: float) -> bool:
        """KV grow OOM: evict the youngest active request (latest arrival,
        highest rid tiebreak) for free-and-recompute re-admission."""
        if not self.active:
            return False
        victim = max(self.active,
                     key=lambda a: (a.rec.arrival_s, a.spec.rid))
        self.active.remove(victim)
        self.kv.release(victim.spec.rid)
        self.kv_blocked = False
        self.pending.appendleft(_Pending(
            spec=victim.spec, arrival_s=victim.rec.arrival_s,
            preempts=victim.rec.preempts + 1))
        victim.rec.preempts += 1
        if self.tracer.enabled:
            self.tracer.instant("kv_preempt", "request", t, tid=self.tid,
                                args={"rid": victim.spec.rid,
                                      "preempts": victim.rec.preempts})
        return True

    def _admit_pending(self) -> List[_Pending]:
        """Move pending requests into a prefill batch while slots and KV
        blocks last.  A request that cannot fit in an *empty* arena is
        dropped (it could never be served)."""
        batch: List[_Pending] = []
        while self.pending and \
                len(self.active) + len(batch) < self.profile.max_batch:
            e = self.pending[0]
            # a request whose *full* context (prompt + every output
            # token) can never fit the arena is unserveable: admitting it
            # would loop admit -> grow-OOM -> self-preempt forever
            if not self.kv.fits_arena(e.spec.prompt_tokens
                                      + e.spec.max_new_tokens):
                self.pending.popleft()
                self._censor(e)
                self.n_dropped += 1
                continue
            if self.kv.try_admit(e.spec.rid, e.spec.prompt_tokens + 1):
                self.pending.popleft()
                batch.append(e)
                continue
            self.kv_blocked = True
            break
        return batch

    # -- the micro event loop ------------------------------------------------
    def advance(self, t0: float, t1: float, phase: PhaseModel) -> None:
        """Advance the server through the active window ``[t0, t1)`` under
        the given phase rates (constant within a scheduler window)."""
        t = max(self.t, t0)
        if t1 <= t + _EPS:
            self.t = max(self.t, t1)
            return
        # convergence guard: count consecutive iterations with NO time
        # progress (a real livelock), not total iterations — an arena
        # near capacity can admit->prefill->preempt the same short
        # request thousands of times per window, and every such cycle
        # still advances t by the prefill pass
        max_stall = 1000 + 50 * len(self._stream)
        stall = 0
        t_prev = -math.inf
        while t < t1 - _EPS:
            if t > t_prev:
                stall, t_prev = 0, t
            else:
                stall += 1
                if stall > max_stall:
                    raise RuntimeError(
                        f"TenantServer {self.tid}: micro loop did not "
                        f"converge (t={t}, window=({t0}, {t1}))")
            self._ingest(t)
            # start a prefill pass when slots and requests are available
            if self.prefill is None:
                batch = self._admit_pending()
                if batch:
                    self.prefill = _Prefill(
                        entries=batch,
                        tokens_left=float(sum(e.spec.prompt_tokens
                                              for e in batch)))
            if self.prefill is not None:
                need_s = self.prefill.tokens_left / phase.prefill_tokens_per_s
                if t + need_s <= t1:
                    t += need_s
                    for e in self.prefill.entries:
                        self._activate(e, first_token_s=t)
                    self.prefill = None
                    continue
                self.prefill.tokens_left -= \
                    (t1 - t) * phase.prefill_tokens_per_s
                t = t1
                break
            if self.active:
                t = self._decode_segment(t, t1, phase)
                continue
            nxt = self._peek_arrival()
            if nxt is None or nxt >= t1:
                t = t1
                break
            t = nxt
        self.t = max(self.t, t1)

    def _decode_segment(self, t: float, t1: float,
                        phase: PhaseModel) -> float:
        """One closed-form decode segment: everybody gains ``dtok`` tokens,
        where the segment ends at the earliest of window end, a request
        arrival that could start a prefill, or the earliest completion."""
        rids = [a.spec.rid for a in self.active]
        kv_bytes = sum(a.ctx_tokens for a in self.active) * \
            self.kv.kv_bytes_per_token
        step_s = max(phase.decode_step_s(kv_bytes,
                                         self.kv.stall_ranges(rids)), 1e-9)
        boundary = t1
        if len(self.active) < self.profile.max_batch:
            nxt = self._peek_arrival()
            if nxt is not None and t < nxt < boundary:
                boundary = nxt
        min_rem = min(a.spec.max_new_tokens - a.produced
                      for a in self.active)
        t_complete = t + min_rem * step_s
        if t_complete <= boundary + _EPS:
            end, dtok = t_complete, min_rem
        else:
            end, dtok = boundary, (boundary - t) / step_s
        # KV growth for this segment's token gain — real buddy allocation,
        # preempting the youngest slot on OOM and re-planning the segment
        preempted = False
        for a in list(self.active):
            if a not in self.active:
                continue                        # preempted by an earlier grow
            need = int(math.ceil(a.ctx_tokens + dtok))
            while not self.kv.try_grow(a.spec.rid, need):
                if not self._preempt_youngest(t):
                    break
                preempted = True
                if a not in self.active:       # preempted itself
                    break
        if preempted:
            # any eviction stales the plan (step time, min_rem and the
            # boundary were computed with the victim in the batch)
            return t
        for a in self.active:
            a.ctx_tokens += dtok
            a.produced += dtok
        done = [a for a in self.active
                if a.produced >= a.spec.max_new_tokens - 1e-9]
        for a in done:
            self.active.remove(a)
            self._finalize(a, end)
        return end

    # -- scheduler-facing ----------------------------------------------------
    def pressure(self) -> PressureSignals:
        return PressureSignals(
            queue_depth=len(self.pending),
            kv_occupancy=self.kv.occupancy(),
            batch_fill=len(self.active) / max(self.profile.max_batch, 1),
            kv_blocked=self.kv_blocked)

    def finish(self) -> List[RequestRecord]:
        """Tenant departed: censor everything in flight — including stream
        entries never ingested because a pause covered the final window
        (every sampled request must appear in exactly one record, whatever
        the policy's pause pattern) — and release KV."""
        self._ingest(self.depart_s)
        if self.prefill is not None:
            for e in self.prefill.entries:
                self._censor(e)
            self.prefill = None
        for a in self.active:
            a.rec.tokens_out = int(a.produced)
        for e in self.pending:
            self._censor(e)
        self.active = []
        self.pending.clear()
        self.kv.release_all()
        return self.records


class _Slot:
    """One active batch slot in the vector engine (the hot per-slot values
    — ctx, produced, target, block mirror — live in the pool's [row, slot]
    arrays at this slot's current position)."""

    __slots__ = ("rid", "ix", "arrival_s", "max_new", "preempts",
                 "first_token_s", "rec")

    def __init__(self, rid: int, ix: int, arrival_s: float, max_new: int,
                 preempts: int, first_token_s: float,
                 rec: Optional[RequestRecord]):
        self.rid = rid
        self.ix = ix                       # index into the tenant's stream
        self.arrival_s = arrival_s
        self.max_new = max_new
        self.preempts = preempts
        self.first_token_s = first_token_s
        self.rec = rec


class _Row:
    """Per-tenant state of the vector engine that is touched only at
    boundary events (Python-side); everything per-iteration lives in the
    pool's numpy arrays, indexed by ``r``."""

    __slots__ = ("tid", "r", "profile", "kv", "arrival_s", "admit_s",
                 "depart_s", "stream", "t_abs", "next_ix", "pending",
                 "slots", "prefill_entries", "records", "first_tok",
                 "kv_blocked", "n_dropped", "emit_buf")

    def __init__(self, tid: int, r: int, profile: ServeProfile,
                 stream: List[RequestSpec], arrival_s: float, admit_s: float,
                 depart_s: float, record: bool):
        self.tid = tid
        self.r = r
        self.profile = profile
        self.kv = TenantKV(profile.kv_arena_bytes, profile.kv_block_bytes,
                           profile.kv_bytes_per_token)
        self.arrival_s = arrival_s
        self.admit_s = admit_s
        self.depart_s = depart_s
        self.stream = stream
        # absolute arrival times (same float adds as the scalar path)
        self.t_abs = np.array([arrival_s + s.t_s for s in stream],
                              dtype=np.float64) if stream else \
            np.empty(0, dtype=np.float64)
        self.next_ix = 0
        # (stream index, preempt count, absolute arrival) — the scalar
        # engine's _Pending, flattened
        self.pending: Deque[Tuple[int, int, float]] = deque()
        self.slots: List[_Slot] = []
        self.prefill_entries: Optional[List[Tuple[int, int, float]]] = None
        self.records: Optional[List[RequestRecord]] = [] if record else None
        # streaming mode: first-token times of preempted requests (the one
        # per-request datum that must survive a preemption)
        self.first_tok: Dict[int, float] = {}
        self.kv_blocked = False
        self.n_dropped = 0
        # completions of the current window, flushed to the plane sink in
        # resident order (matches the scalar engine's emission order)
        self.emit_buf: List[Tuple[float, float, int, bool]] = []


class _VectorPool:
    """Struct-of-arrays continuous batching across all resident tenants.

    ``advance_all`` runs one lockstep loop: each iteration advances every
    tenant still inside the window through exactly one scalar-engine
    micro-iteration, with the segment arithmetic vectorized across
    tenants and the boundary events handled per tenant in Python.  See
    the module docstring for the bit-identity argument.
    """

    B = MAX_BATCH_SLOTS

    def __init__(self):
        self.rows: Dict[int, _Row] = {}         # tid -> row
        self._by_index: List[Optional[_Row]] = []
        self._free: List[int] = []
        self._cap = 0
        self.tracer = Tracer.NULL               # set by the plane
        self._alloc(16)

    # -- storage -------------------------------------------------------------
    def _alloc(self, cap: int) -> None:
        def grow1(name, dtype, fill):
            old = getattr(self, name, None)
            arr = np.full(cap, fill, dtype=dtype)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, name, arr)

        def grow2(name, dtype):
            old = getattr(self, name, None)
            arr = np.zeros((cap, self.B), dtype=dtype)
            if old is not None:
                arr[:len(old)] = old
            setattr(self, name, arr)

        grow1("t_cur", np.float64, 0.0)
        grow1("next_arr", np.float64, np.inf)
        grow1("pref_left", np.float64, 0.0)
        grow1("pref_rate", np.float64, 1.0)
        grow1("base_c", np.float64, 0.0)
        grow1("hbm_bpc", np.float64, 1.0)
        grow1("stall_c", np.float64, 0.0)
        grow1("freq", np.float64, 1.0)
        grow1("bpt_f", np.float64, 1.0)
        grow1("maxb", np.int64, 0)
        grow1("n_act", np.int64, 0)
        grow1("n_pend", np.int64, 0)
        grow1("iter_ct", np.int64, 0)
        grow1("max_iter", np.int64, 0)
        grow1("last_t", np.float64, -np.inf)
        grow1("has_pref", np.bool_, False)
        grow2("ctx", np.float64)
        grow2("prod", np.float64)
        grow2("maxnew_f", np.float64)
        grow2("nblocks", np.float64)
        grow2("cap_eff", np.float64)
        self._by_index.extend([None] * (cap - self._cap))
        self._cap = cap

    # -- lifecycle -----------------------------------------------------------
    def attach(self, tid: int, profile: ServeProfile,
               stream: List[RequestSpec], arrival_s: float, admit_s: float,
               depart_s: float, record: bool) -> None:
        if profile.max_batch > self.B:
            raise ValueError(
                f"profile max_batch {profile.max_batch} exceeds the vector "
                f"engine's slot axis ({self.B})")
        if self._free:
            r = self._free.pop()
        else:
            r = len(self.rows)
            while r < self._cap and self._by_index[r] is not None:
                r += 1
            if r >= self._cap:
                self._alloc(self._cap * 2)
        row = _Row(tid, r, profile, stream, arrival_s, admit_s, depart_s,
                   record)
        self.rows[tid] = row
        self._by_index[r] = row
        self.t_cur[r] = admit_s
        self.next_arr[r] = row.t_abs[0] if len(row.t_abs) else np.inf
        self.pref_left[r] = 0.0
        self.has_pref[r] = False
        self.maxb[r] = profile.max_batch
        self.n_act[r] = 0
        self.n_pend[r] = 0
        self.bpt_f[r] = float(profile.kv_bytes_per_token)

    def detach(self, tid: int) -> ServerFold:
        row = self.rows.pop(tid)
        r = row.r
        # scalar finish(): ingest to departure, censor prefill + actives +
        # pending, release KV — same order
        self._ingest_row(r, row, row.depart_s)
        if row.records is not None:
            if row.prefill_entries is not None:
                for ix, pre, arr in row.prefill_entries:
                    self._censor(row, ix, pre, arr)
            for pos, s in enumerate(row.slots):
                s.rec.tokens_out = int(float(self.prod[r, pos]))
            for ix, pre, arr in row.pending:
                self._censor(row, ix, pre, arr)
            records = row.records
            fold = ServerFold(
                records=records, n_requests=len(records),
                censored_tokens=sum(rec.tokens_out for rec in records
                                    if not rec.completed),
                kv_stats=row.kv.stats, n_dropped=row.n_dropped,
                n_incomplete=sum(1 for rec in records
                                 if not rec.completed) - row.n_dropped)
        else:
            censored = sum(int(float(self.prod[r, pos]))
                           for pos in range(int(self.n_act[r])))
            n_prefill = (len(row.prefill_entries)
                         if row.prefill_entries is not None else 0)
            fold = ServerFold(
                records=None, n_requests=len(row.stream),
                censored_tokens=censored,
                kv_stats=row.kv.stats, n_dropped=row.n_dropped,
                n_incomplete=(int(self.n_act[r]) + len(row.pending)
                              + n_prefill))
        row.kv.release_all()
        row.slots = []
        row.pending.clear()
        self._by_index[r] = None
        self._free.append(r)
        return fold

    # -- boundary events (per-tenant Python, scalar-engine order) ------------
    def _ingest_row(self, r: int, row: _Row, t: float) -> None:
        stream, t_abs = row.stream, row.t_abs
        n = len(stream)
        while row.next_ix < n and t_abs[row.next_ix] <= t + _EPS:
            spec = stream[row.next_ix]
            row.pending.append((row.next_ix, 0, row.arrival_s + spec.t_s))
            row.next_ix += 1
        self.next_arr[r] = t_abs[row.next_ix] if row.next_ix < n else np.inf
        self.n_pend[r] = len(row.pending)

    def _censor(self, row: _Row, ix: int, preempts: int,
                arrival_s: float) -> None:
        spec = row.stream[ix]
        if not any(rec.rid == spec.rid for rec in row.records):
            row.records.append(RequestRecord(
                tid=row.tid, rid=spec.rid, cls=spec.cls,
                arrival_s=arrival_s, prompt_tokens=spec.prompt_tokens,
                target_tokens=spec.max_new_tokens, preempts=preempts))

    def _try_start_prefill(self, r: int, row: _Row) -> None:
        kv = row.kv
        batch: List[Tuple[int, int, float]] = []
        while row.pending and \
                int(self.n_act[r]) + len(batch) < row.profile.max_batch:
            ix, pre, arr = row.pending[0]
            spec = row.stream[ix]
            if not kv.fits_arena(spec.prompt_tokens + spec.max_new_tokens):
                row.pending.popleft()
                if row.records is not None:
                    self._censor(row, ix, pre, arr)
                row.n_dropped += 1
                continue
            if kv.try_admit(spec.rid, spec.prompt_tokens + 1):
                row.pending.popleft()
                batch.append((ix, pre, arr))
                continue
            row.kv_blocked = True
            break
        self.n_pend[r] = len(row.pending)
        if batch:
            row.prefill_entries = batch
            self.has_pref[r] = True
            self.pref_left[r] = float(sum(row.stream[ix].prompt_tokens
                                          for ix, _, _ in batch))

    def _finish_prefill(self, r: int, row: _Row) -> None:
        t = float(self.t_cur[r])
        kv = row.kv
        for ix, pre, arr in row.prefill_entries:
            spec = row.stream[ix]
            rec = None
            if row.records is not None:
                rec = RequestRecord(
                    tid=row.tid, rid=spec.rid, cls=spec.cls, arrival_s=arr,
                    prompt_tokens=spec.prompt_tokens,
                    target_tokens=spec.max_new_tokens, preempts=pre)
                if pre:
                    for rr in row.records:
                        if rr.rid == spec.rid:
                            rec = rr
                            rec.preempts = pre
                            break
                    else:
                        row.records.append(rec)
                else:
                    row.records.append(rec)
                if rec.first_token_s is None:
                    rec.first_token_s = t
                ft = rec.first_token_s
            else:
                ft = row.first_tok.get(spec.rid)
                if ft is None:
                    ft = t
            pos = int(self.n_act[r])
            row.slots.append(_Slot(spec.rid, ix, arr, spec.max_new_tokens,
                                   pre, ft, rec))
            self.ctx[r, pos] = float(spec.prompt_tokens + 1)
            self.prod[r, pos] = 1.0
            self.maxnew_f[r, pos] = float(spec.max_new_tokens)
            nb = kv.n_ranges(spec.rid)
            self.nblocks[r, pos] = nb
            self.cap_eff[r, pos] = kv.capacity_limit_tokens(spec.rid)
            self.n_act[r] = pos + 1
        row.prefill_entries = None
        self.has_pref[r] = False

    def _remove_slot(self, r: int, pos: int, row: _Row) -> None:
        k = int(self.n_act[r])
        for arr in (self.ctx, self.prod, self.maxnew_f, self.nblocks,
                    self.cap_eff):
            arr[r, pos:k - 1] = arr[r, pos + 1:k]
        row.slots.pop(pos)
        self.n_act[r] = k - 1

    def _preempt_youngest(self, r: int, row: _Row) -> bool:
        if not row.slots:
            return False
        victim = max(row.slots, key=lambda s: (s.arrival_s, s.rid))
        pos = row.slots.index(victim)
        self._remove_slot(r, pos, row)
        row.kv.release(victim.rid)
        row.kv_blocked = False
        victim.preempts += 1
        if row.records is not None:
            victim.rec.preempts = victim.preempts
        elif victim.rid not in row.first_tok:
            row.first_tok[victim.rid] = victim.first_token_s
        row.pending.appendleft((victim.ix, victim.preempts,
                                victim.arrival_s))
        self.n_pend[r] = len(row.pending)
        if self.tracer.enabled:
            self.tracer.instant("kv_preempt", "request",
                                float(self.t_cur[r]), tid=row.tid,
                                args={"rid": victim.rid,
                                      "preempts": victim.preempts})
        return True

    def _grow_row(self, r: int, row: _Row, dtok: float) -> bool:
        """The scalar engine's KV-growth loop, verbatim: try_grow every
        slot in snapshot order, preempting the youngest on OOM.  Returns
        True when any slot was evicted (the segment plan is stale)."""
        kv = row.kv
        preempted = False
        for s in list(row.slots):
            if s not in row.slots:
                continue                       # preempted by an earlier grow
            pos = row.slots.index(s)
            need = int(math.ceil(float(self.ctx[r, pos]) + dtok))
            ok = kv.try_grow(s.rid, need)
            while not ok:
                if not self._preempt_youngest(r, row):
                    break
                preempted = True
                if s not in row.slots:         # preempted itself
                    break
                ok = kv.try_grow(s.rid, need)
            if ok and s in row.slots:
                pos = row.slots.index(s)
                self.nblocks[r, pos] = kv.n_ranges(s.rid)
                self.cap_eff[r, pos] = kv.capacity_limit_tokens(s.rid)
        return preempted

    def _complete_row(self, r: int, row: _Row, sink_live: bool) -> None:
        end = float(self.t_cur[r])
        k = int(self.n_act[r])
        done = [row.slots[j] for j in range(k)
                if float(self.prod[r, j])
                >= float(self.maxnew_f[r, j]) - 1e-9]
        prof = row.profile
        for s in done:
            pos = row.slots.index(s)
            self._remove_slot(r, pos, row)
            row.kv.release(s.rid)
            row.kv_blocked = False
            if s.rec is not None:
                s.rec.done_s = end
                s.rec.tokens_out = s.max_new
                ttft = s.rec.ttft_s
                tpot = s.rec.tpot_s
            else:
                ttft = s.first_token_s - s.arrival_s
                tpot = 0.0 if s.max_new <= 1 else \
                    (end - s.first_token_s) / (s.max_new - 1)
                row.first_tok.pop(s.rid, None)
            if sink_live:
                good = ttft <= prof.ttft_slo_s and tpot <= prof.tpot_slo_s
                row.emit_buf.append((ttft, tpot, s.max_new, good))
            if self.tracer.enabled:
                ft = s.first_token_s
                self.tracer.span(
                    "prefill", "request", s.arrival_s, ft - s.arrival_s,
                    tid=row.tid,
                    args={"rid": s.rid,
                          "prompt_tokens":
                          row.stream[s.ix].prompt_tokens})
                self.tracer.span(
                    "decode", "request", ft, end - ft, tid=row.tid,
                    args={"rid": s.rid, "tokens": s.max_new,
                          "preempts": s.preempts})

    # -- the lockstep loop ---------------------------------------------------
    def advance_all(self, entries: List[Tuple[int, float, PhaseModel]],
                    t1: float, sink_live: bool) -> None:
        B = self.B
        idx_list = []
        for tid, w0, pm in entries:
            row = self.rows[tid]
            r = row.r
            self.t_cur[r] = max(float(self.t_cur[r]), w0)
            self.pref_rate[r] = pm.prefill_tokens_per_s
            self.base_c[r] = pm.step_base_cycles
            self.hbm_bpc[r] = pm.hbm_bytes_per_cycle
            self.stall_c[r] = float(pm.stall_cycles_per_range)
            self.freq[r] = pm.freq_hz
            self.iter_ct[r] = 0
            self.max_iter[r] = 1000 + 50 * len(row.stream)
            self.last_t[r] = -np.inf
            idx_list.append(r)
        idx = np.array(idx_list, dtype=np.int64)
        cols = np.arange(B)

        act = idx[self.t_cur[idx] < t1 - _EPS]
        while act.size:
            # convergence guard: consecutive NO-progress iterations only
            # (matches the scalar engine) — admit->preempt thrash near
            # arena capacity runs many micro iterations per window while
            # still advancing every row's clock
            moved = self.t_cur[act] > self.last_t[act]
            self.iter_ct[act] = np.where(moved, 0, self.iter_ct[act] + 1)
            self.last_t[act] = self.t_cur[act]
            if np.any(self.iter_ct[act] > self.max_iter[act]):
                bad = act[self.iter_ct[act] > self.max_iter[act]][0]
                tid = self._by_index[int(bad)].tid
                raise RuntimeError(
                    f"TenantServer {tid}: micro loop did not converge "
                    f"(t={float(self.t_cur[bad])}, window=(.., {t1}))")
            # 1. ingest arrivals due at the current per-row time
            for r in act[self.next_arr[act] <= self.t_cur[act] + _EPS]:
                r = int(r)
                self._ingest_row(r, self._by_index[r],
                                 float(self.t_cur[r]))
            # 2. admission -> prefill start (rows with no prefill in
            # flight, pending work and a free slot; the scalar loop's
            # _admit_pending is a no-op otherwise)
            cand = act[(~self.has_pref[act]) & (self.n_pend[act] > 0)
                       & (self.n_act[act] < self.maxb[act])]
            for r in cand:
                r = int(r)
                self._try_start_prefill(r, self._by_index[r])
            # 3. classify — each row does exactly one scalar iteration
            hp = self.has_pref[act]
            na = self.n_act[act]
            pre = act[hp]
            dec = act[(~hp) & (na > 0)]
            idl = act[(~hp) & (na == 0)]
            # -- prefill rows: drain tokens_left at the prefill rate
            if pre.size:
                rate = self.pref_rate[pre]
                tc = self.t_cur[pre]
                tdone = tc + self.pref_left[pre] / rate
                finm = tdone <= t1
                unf = pre[~finm]
                self.pref_left[unf] -= (t1 - self.t_cur[unf]) \
                    * self.pref_rate[unf]
                self.t_cur[unf] = t1
                fin = pre[finm]
                self.t_cur[fin] = tdone[finm]
                for r in fin:
                    r = int(r)
                    self._finish_prefill(r, self._by_index[r])
            # -- decode rows: one closed-form segment, vectorized
            if dec.size:
                k = self.n_act[dec]
                acc = np.zeros(len(dec))
                rng_acc = np.zeros(len(dec))
                rem = np.full(len(dec), np.inf)
                for j in range(B):
                    m = j < k
                    acc = acc + np.where(m, self.ctx[dec, j], 0.0)
                    rng_acc = rng_acc + np.where(m, self.nblocks[dec, j],
                                                 0.0)
                    rem = np.minimum(rem, np.where(
                        m, self.maxnew_f[dec, j] - self.prod[dec, j],
                        np.inf))
                kvb = acc * self.bpt_f[dec]
                step = (self.base_c[dec] + kvb / self.hbm_bpc[dec]
                        + rng_acc * self.stall_c[dec]) / self.freq[dec]
                step = np.maximum(step, 1e-9)
                tc = self.t_cur[dec]
                nxt = self.next_arr[dec]
                arr_cut = (k < self.maxb[dec]) & (tc < nxt) & (nxt < t1)
                boundary = np.where(arr_cut, nxt, t1)
                t_comp = tc + rem * step
                compm = t_comp <= boundary + _EPS
                end = np.where(compm, t_comp, boundary)
                dtok = np.where(compm, rem, (boundary - tc) / step)
                # KV growth: slots whose token gain crosses a block
                # boundary take the scalar grow/preempt path; everyone
                # else's try_grow would be an allocation-free no-op
                # (cap_eff is the exact inverse of _blocks_for)
                cm = cols[None, :] < k[:, None]
                needc = np.ceil(self.ctx[dec] + dtok[:, None])
                slow = (needc > self.cap_eff[dec]) & cm
                preempted: set = set()
                for p in np.nonzero(slow.any(axis=1))[0]:
                    r = int(dec[p])
                    if self._grow_row(r, self._by_index[r],
                                      float(dtok[p])):
                        preempted.add(r)
                if preempted:
                    keep = np.array([int(r) not in preempted for r in dec])
                else:
                    keep = np.ones(len(dec), dtype=bool)
                u = dec[keep]
                if u.size:
                    dt_u = dtok[keep]
                    cmu = cols[None, :] < self.n_act[u][:, None]
                    gain = np.where(cmu, dt_u[:, None], 0.0)
                    self.ctx[u] += gain
                    self.prod[u] += gain
                    self.t_cur[u] = end[keep]
                    donem = (self.prod[u] >= self.maxnew_f[u] - 1e-9) & cmu
                    for p in np.nonzero(donem.any(axis=1))[0]:
                        r = int(u[p])
                        self._complete_row(r, self._by_index[r], sink_live)
            # -- idle rows: jump to the next arrival (or the window end)
            if idl.size:
                nxt = self.next_arr[idl]
                self.t_cur[idl] = np.where(nxt < t1, nxt, t1)
            act = idx[self.t_cur[idx] < t1 - _EPS]
        self.t_cur[idx] = np.maximum(self.t_cur[idx], t1)

    # -- scheduler-facing ----------------------------------------------------
    def busy(self, tid: int) -> bool:
        row = self.rows[tid]
        r = row.r
        return bool(self.n_act[r] > 0 or row.pending
                    or self.has_pref[r])

    def pressure(self, tid: int) -> PressureSignals:
        row = self.rows[tid]
        r = row.r
        return PressureSignals(
            queue_depth=len(row.pending),
            kv_occupancy=row.kv.occupancy(),
            batch_fill=int(self.n_act[r]) / max(row.profile.max_batch, 1),
            kv_blocked=row.kv_blocked)

    def live_records(self) -> int:
        return sum(len(row.records) for row in self.rows.values()
                   if row.records is not None)


class ServingPlane:
    """All resident tenant servers of one scheduler run.

    ``engine`` selects the scalar reference (:class:`TenantServer` per
    tenant) or the vectorized pool (default) — trajectories are
    bit-identical.  ``record_requests=False`` drops per-request records
    entirely (vector engine): completions stream through ``sink`` and
    ``detach`` returns aggregates only.  ``arrival`` / ``rate_scale`` /
    ``mix`` shape every tenant's request stream (see
    :mod:`repro.serve.requests`).
    """

    ENGINES = ("vector", "scalar")

    def __init__(self, seed: int = 0, engine: str = "vector",
                 record_requests: bool = True,
                 arrival: Optional[ArrivalProcess] = None,
                 rate_scale: float = 1.0, mix: str = "default",
                 sink: Optional[Sink] = None):
        if engine not in self.ENGINES:
            raise ValueError(
                f"engine must be one of {self.ENGINES}, got {engine!r}")
        self.seed = seed
        self.engine = engine
        self.record_requests = record_requests
        self.arrival = arrival
        self.rate_scale = rate_scale
        self.mix = mix
        self.sink = sink
        # pure-observer span tracer (the scheduler rebinds this right
        # after construction); threaded into both engines at attach time
        self.tracer = Tracer.NULL
        self.servers: Dict[int, TenantServer] = {}        # scalar engine
        self._pool: Optional[_VectorPool] = (
            _VectorPool() if engine == "vector" else None)
        #: high-water mark of simultaneously-resident RequestRecord
        #: objects across all attached tenants (the memory-audit metric:
        #: 0 in streaming mode, O(active tenants x stream) in record mode)
        self.peak_live_records = 0
        # EWMA of observed prefill rates (tokens/s) across every advance —
        # the scheduler's SLA-aware admission predicts a queued tenant's
        # TTFT at *current* load from this
        self._prefill_rate_ewma = 0.0

    @property
    def n_attached(self) -> int:
        return len(self._pool.rows) if self._pool is not None \
            else len(self.servers)

    def request_seed(self, tid: int) -> int:
        return (self.seed * 1_000_003 + tid) & 0x7FFFFFFF

    def attach(self, tid: int, model: str, arrival_s: float, admit_s: float,
               depart_s: float) -> bool:
        """Start serving a newly-admitted tenant.  Returns False (no-op)
        for models without a serving profile (CNN frame tenants).  The
        request stream spans the tenant's service duration but is anchored
        at its cluster *arrival* — requests that arrived during the
        admission wait are backlogged, so queue latency surfaces as TTFT.
        """
        profile = get_profile(model)
        if profile is None:
            return False
        stream = sample_requests(profile, depart_s - admit_s,
                                 self.request_seed(tid),
                                 arrival=self.arrival,
                                 rate_scale=self.rate_scale, mix=self.mix)
        if self._pool is not None:
            self._pool.tracer = self.tracer
            self._pool.attach(tid, profile, stream, arrival_s, admit_s,
                              depart_s, record=self.record_requests)
        else:
            self.servers[tid] = TenantServer(
                tid, profile, stream, arrival_s, admit_s, depart_s,
                sink=self._emit, tracer=self.tracer)
        return True

    def is_attached(self, tid: int) -> bool:
        return tid in (self._pool.rows if self._pool is not None
                       else self.servers)

    def profile(self, tid: int) -> ServeProfile:
        if self._pool is not None:
            return self._pool.rows[tid].profile
        return self.servers[tid].profile

    def busy(self, tid: int) -> bool:
        """Work in flight?  (The HBM-streamer census asks this.)"""
        if self._pool is not None:
            return self._pool.busy(tid)
        s = self.servers[tid]
        return bool(s.active or s.pending or s.prefill is not None)

    def _emit(self, ttft: float, tpot: float, tokens: int,
              good: bool) -> None:
        if self.sink is not None:
            self.sink(ttft, tpot, tokens, good)

    def advance(self, tid: int, t0: float, t1: float,
                phase: PhaseModel) -> None:
        """Single-tenant advance (legacy API): one-entry ``advance_all``."""
        self.advance_all([(tid, t0, phase)], t1)

    def advance_all(self, entries: List[Tuple[int, float, PhaseModel]],
                    t1: float) -> None:
        """Advance every listed tenant through ``[w0_i, t1)`` under its
        phase model — the scheduler's one call per integration window.
        Completion emission order is identical across engines: per tenant
        in ``entries`` order, time-ordered within a tenant."""
        for _, _, pm in entries:
            r = pm.prefill_tokens_per_s
            self._prefill_rate_ewma = r if self._prefill_rate_ewma == 0.0 \
                else 0.9 * self._prefill_rate_ewma + 0.1 * r
        if self._pool is not None:
            self._pool.advance_all(entries, t1,
                                   sink_live=self.sink is not None)
            for tid, _, _ in entries:
                row = self._pool.rows[tid]
                if row.emit_buf:
                    for e in row.emit_buf:
                        self.sink(*e)
                    row.emit_buf.clear()
            live = self._pool.live_records()
        else:
            for tid, w0, pm in entries:
                self.servers[tid].advance(w0, t1, pm)
            live = sum(len(s.records) for s in self.servers.values())
        if live > self.peak_live_records:
            self.peak_live_records = live

    def predicted_prefill_s(self, profile: ServeProfile) -> float:
        """Predicted TTFT contribution of one mean-sized prompt at the
        currently-observed cluster prefill rate (0 before any window ran):
        what SLA-aware admission subtracts from a queued tenant's
        deadline."""
        if self._prefill_rate_ewma <= 0.0:
            return 0.0
        w = sum(c.weight for c in profile.classes)
        mean_prompt = sum(c.weight * c.prompt_mean
                          for c in profile.classes) / max(w, 1e-9)
        return mean_prompt / self._prefill_rate_ewma

    def pressure(self, tid: int) -> PressureSignals:
        if self._pool is not None:
            return self._pool.pressure(tid)
        return self.servers[tid].pressure()

    def detach(self, tid: int) -> ServerFold:
        """Tenant departed: finalize its in-flight requests, release the KV
        arena, and return the fold for metrics aggregation."""
        if self._pool is not None:
            return self._pool.detach(tid)
        server = self.servers.pop(tid)
        records = server.finish()
        return ServerFold(
            records=records if self.record_requests else None,
            n_requests=len(records),
            censored_tokens=sum(rec.tokens_out for rec in records
                                if not rec.completed),
            kv_stats=server.kv.stats, n_dropped=server.n_dropped,
            n_incomplete=sum(1 for rec in records
                             if not rec.completed) - server.n_dropped)
