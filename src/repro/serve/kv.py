"""KV-cache occupancy over the tenant's vNPU memory.

Each serving tenant owns a KV arena carved from its vNPU's global-memory
grant and managed by the *real* :class:`~repro.core.buddy.BuddyAllocator`
(§5.2's allocator — the same one the hypervisor uses for weights), so
decode batches hit real out-of-memory conditions: a request is admitted to
the batch only if its KV blocks allocate, growth past a block boundary can
fail mid-decode (triggering vLLM-style preempt-youngest recompute), and
fragmentation of the buddy free lists is the fragmentation the scheduler's
pressure signals see.

Every allocated block is one range-translation-table entry
(:class:`~repro.core.vchunk.RTTEntry`), exactly as the hypervisor records
weight blocks, so decode address translation pays the paper's RTT walk
cost: with the RTT_CUR cursor each per-step re-walk is one entry read per
range (Pattern 2 of §5.3), i.e. ``n_ranges x rtt_entry_read_cycles`` stall
cycles per decode step per request — :meth:`TenantKV.stall_ranges` feeds
that into the phase model, and :meth:`TenantKV.rtt_for` materializes the
real table so tests can cross-check the analytic count against a
trace-driven :class:`~repro.core.vchunk.RangeTLB` walk.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from ..core.buddy import BuddyAllocator, OutOfMemory
from ..core.vchunk import RangeTranslationTable, RTTEntry


@dataclasses.dataclass
class KVStats:
    """Cumulative KV-arena telemetry for one tenant."""
    admit_oom: int = 0          # admissions deferred because blocks wouldn't fit
    grow_oom: int = 0           # mid-decode growth failures (trigger preemption)
    blocks_allocated: int = 0
    peak_occupancy: float = 0.0


class TenantKV:
    """One tenant's KV arena: block-granular reservations per request.

    ``capacity_tokens(rid)`` is what the allocated blocks can hold;
    admission reserves the prompt (plus the prefill's first output token)
    and decode growth allocates lazily at segment boundaries.  All methods
    are O(blocks touched); the buddy keeps its own invariants
    (``check_invariants`` is exercised by the property tests).
    """

    def __init__(self, arena_bytes: int, block_bytes: int,
                 kv_bytes_per_token: int):
        self.buddy = BuddyAllocator(arena_bytes, min_block=block_bytes)
        self.block_bytes = block_bytes
        self.kv_bytes_per_token = kv_bytes_per_token
        self._blocks: Dict[int, List[int]] = {}   # rid -> block addrs
        self.stats = KVStats()

    # -- geometry ------------------------------------------------------------
    def tokens_per_block(self) -> int:
        return max(1, self.block_bytes // self.kv_bytes_per_token)

    def capacity_tokens(self, rid: int) -> int:
        return len(self._blocks.get(rid, ())) * self.tokens_per_block()

    def occupancy(self) -> float:
        """Fraction of the arena held by live KV blocks (the scheduler's
        memory-pressure resize signal)."""
        return self.buddy.used_bytes() / self.buddy.total

    def fits_arena(self, tokens: int) -> bool:
        """Could ``tokens`` of KV ever fit this arena, even empty?  A
        request whose full context fails this is unserveable and must be
        dropped up front (admitting it would preempt-recompute forever)."""
        return self._blocks_for(tokens) <= self.buddy.total // self.block_bytes

    def n_ranges(self, rid: int) -> int:
        return len(self._blocks.get(rid, ()))

    def stall_ranges(self, rids: Iterable[int]) -> int:
        """Total RTT ranges the active batch re-walks per decode step —
        multiply by ``HWConfig.rtt_entry_read_cycles`` for the stall."""
        return sum(self.n_ranges(r) for r in rids)

    def block_counts(self, rids: Iterable[int]) -> np.ndarray:
        """Batched ``n_ranges`` — one arena query for a whole batch (the
        vectorized plane refreshes its per-slot block mirror from this)."""
        return np.fromiter((len(self._blocks.get(r, ())) for r in rids),
                           dtype=np.int64)

    def capacity_limit_tokens(self, rid: int) -> int:
        """Largest token count the request's current blocks can hold
        without another allocation: the exact inverse of
        ``_blocks_for`` (``tokens <= n_blocks * block_bytes // bpt`` iff
        ``try_grow`` would be an allocation-free no-op) — the vectorized
        plane's O(1) precheck for skipping per-slot grow calls."""
        return (len(self._blocks.get(rid, ())) * self.block_bytes
                // self.kv_bytes_per_token)

    # -- lifecycle -----------------------------------------------------------
    def _alloc_blocks(self, rid: int, n: int) -> bool:
        got: List[int] = []
        for _ in range(n):
            try:
                addr, _ = self.buddy.alloc(self.block_bytes)
            except OutOfMemory:
                for a in got:
                    self.buddy.free_block(a)
                return False
            got.append(addr)
        self._blocks.setdefault(rid, []).extend(got)
        self.stats.blocks_allocated += len(got)
        self.stats.peak_occupancy = max(self.stats.peak_occupancy,
                                        self.occupancy())
        return True

    def _blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) * self.kv_bytes_per_token
                 // self.block_bytes)

    def try_admit(self, rid: int, tokens: int) -> bool:
        """Reserve blocks for ``tokens`` (prompt + first output).  All-or-
        nothing; a failure leaves the arena untouched and defers the
        request (it stays pending until completions free blocks)."""
        if rid in self._blocks:
            raise ValueError(f"request {rid} already admitted")
        if self._alloc_blocks(rid, self._blocks_for(tokens)):
            return True
        self.stats.admit_oom += 1
        return False

    def try_grow(self, rid: int, tokens: int) -> bool:
        """Ensure capacity for ``tokens``; False on OOM (the plane then
        preempts the youngest active request and retries)."""
        need = self._blocks_for(tokens) - self.n_ranges(rid)
        if need <= 0:
            return True
        if self._alloc_blocks(rid, need):
            return True
        self.stats.grow_oom += 1
        return False

    def release(self, rid: int) -> None:
        """Free every block of a finished (or preempted) request."""
        for addr in self._blocks.pop(rid, ()):
            self.buddy.free_block(addr)

    def release_all(self) -> None:
        for rid in list(self._blocks):
            self.release(rid)

    # -- cross-check hook ----------------------------------------------------
    def rtt_for(self, rid: int) -> Optional[RangeTranslationTable]:
        """The request's KV ranges as a real RTT (vaddr-contiguous, one
        entry per buddy block) — lets tests drive the actual
        :class:`~repro.core.vchunk.RangeTLB` against the analytic
        ``n_ranges`` stall count."""
        blocks = self._blocks.get(rid)
        if not blocks:
            return None
        rtt = RangeTranslationTable()
        va = 0
        for addr in blocks:
            rtt.insert(RTTEntry(vaddr=va, paddr=addr, size=self.block_bytes))
            va += self.block_bytes
        return rtt
