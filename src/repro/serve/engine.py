"""Batched serving engine: continuous-batching prefill/decode on a virtual
NPU submesh.

Requests queue up, get micro-batched into a fixed-size decode batch
(padding with idle slots), prefill seeds each slot's KV cache, and a single
jit'd decode step advances every active slot one token per tick — the
standard orchestration loop of an LLM server, runnable on CPU for the
examples/tests and shape-identical to the decode dry-run cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 4
    max_seq: int = 256
    greedy: bool = True


def seed_decode_cache(bundle, prefill_caches, batch_size: int, max_seq: int):
    """Copy prefill K/V (length S) into fresh max_seq decode caches.

    For sliding-window rings this is exact while prompt_len <= window (ring
    slot i == absolute position i); longer prompts re-wrap consistently with
    update_cache's pos % S indexing.  SSM states/conv tails pass through
    unchanged (no sequence dim).
    """
    caches = bundle.init_cache(batch_size, max_seq)

    def seed(dst, src):
        if src is None:
            return dst
        if src.shape == dst.shape:
            return src
        if dst.ndim >= 4 and src.ndim == dst.ndim and \
                src.shape[2] != dst.shape[2]:
            n = min(src.shape[2], dst.shape[2])
            return dst.at[:, :, :n].set(src[:, :, src.shape[2] - n:])
        return dst

    out = []
    for dst_stack, src_stack in zip(caches, prefill_caches):
        if src_stack is None:
            out.append(dst_stack)
        else:
            out.append(jax.tree.map(seed, dst_stack, src_stack))
    return out


class ServeEngine:
    """Single-host engine over a ModelBundle (works meshed or unmeshed)."""

    def __init__(self, bundle, params, ecfg: EngineConfig):
        self.bundle = bundle
        self.params = params
        self.ecfg = ecfg
        self.cfg = bundle.cfg
        self._decode = jax.jit(bundle.decode)
        self._prefill = jax.jit(bundle.prefill)
        self.queue: List[Request] = []
        self.stats: Dict[str, float] = {"prefills": 0, "decode_steps": 0,
                                        "tokens_out": 0}

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        req = Request(rid=len(self.queue), prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens)
        self.queue.append(req)
        return req

    # -- batch plumbing ------------------------------------------------------
    def _pad_batch(self, reqs: List[Request]) -> Dict[str, jnp.ndarray]:
        B = self.ecfg.batch_size
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, self.cfg.frontend_seq, self.cfg.frontend_dim),
                jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.enc_seq, self.cfg.frontend_dim), jnp.bfloat16)
        return batch, S

    def _seed_cache(self, prefill_caches, prompt_len: int):
        return seed_decode_cache(self.bundle, prefill_caches,
                                 self.ecfg.batch_size, self.ecfg.max_seq)

    # -- main loop -----------------------------------------------------------
    def run(self, max_ticks: int = 64) -> List[Request]:
        """Process the queue to completion (or tick budget)."""
        pending = [r for r in self.queue if not r.done]
        while pending and max_ticks > 0:
            reqs = pending[: self.ecfg.batch_size]
            batch, S = self._pad_batch(reqs)
            last_logits, caches = self._prefill(self.params, batch)
            self.stats["prefills"] += 1
            caches = self._seed_cache(caches, S)
            tok = jnp.argmax(last_logits[..., : self.cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            for i, r in enumerate(reqs):
                r.out_tokens.append(int(tok[i, 0]))
            pos = S
            steps = max(r.max_new_tokens for r in reqs) - 1
            for _ in range(min(steps, max_ticks)):
                logits, caches = self._decode(self.params, caches, tok,
                                              jnp.int32(pos))
                tok = jnp.argmax(logits[..., : self.cfg.vocab_size],
                                 axis=-1).astype(jnp.int32)
                self.stats["decode_steps"] += 1
                for i, r in enumerate(reqs):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(tok[i, 0]))
                        self.stats["tokens_out"] += 1
                pos += 1
                max_ticks -= 1
            for r in reqs:
                r.done = True
            pending = [r for r in self.queue if not r.done]
        return self.queue
