"""Streaming latency statistics: exact counters + P² percentile sketches.

At million-request scale the serving plane cannot materialize per-request
latency lists (the O(requests) memory the PR-6 audit removes), so
:class:`LatencyStats` keeps

* exact count / sum / min / max (SLA-goodput itself is an exact counter
  kept by :class:`~repro.sched.cluster.ClusterMetrics` — only the latency
  *percentiles* are sketched);
* the raw sample buffer while small (``CUTOVER`` observations), where
  quantiles are computed exactly (numpy's linear interpolation, matching
  the list-based percentiles this replaces bit-for-bit);
* beyond that, one Jain & Chlamtac P² marker set per tracked quantile
  (p50 / p95 / p99): O(1) memory and O(1) deterministic float arithmetic
  per observation, no randomization — identical feed order gives identical
  sketches, which is what lets the scalar and vectorized serving engines
  be compared on full ``serving_summary()`` equality.
"""
from __future__ import annotations

import bisect
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

#: quantiles every LatencyStats tracks once it switches to sketching
TRACKED_QUANTILES: Tuple[float, ...] = (0.50, 0.95, 0.99)


class P2Quantile:
    """One P² (piecewise-parabolic) streaming quantile estimator.

    Five markers track (min, p/2, p, (1+p)/2, max); each observation moves
    the middle markers toward their desired positions with a parabolic
    (fallback: linear) height adjustment.  Exact for the first five
    observations; a deterministic O(1) approximation after.
    """

    __slots__ = ("p", "_q", "_n", "_np", "_dn", "count")

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {p}")
        self.p = p
        self._q: List[float] = []          # marker heights
        self._n = [0, 1, 2, 3, 4]          # marker positions (0-based)
        self._np = [0.0, 2 * p, 4 * p, 2 + 2 * p, 4.0]   # desired positions
        self._dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]     # position increments
        self.count = 0

    def add(self, x: float) -> None:
        self.count += 1
        q, n = self._q, self._n
        if self.count <= 5:
            q.append(x)
            q.sort()
            return
        # locate the cell and clamp the extreme markers
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 4):
                if x >= q[i]:
                    k = i
        for i in range(k + 1, 5):
            n[i] += 1
        for i in range(5):
            self._np[i] += self._dn[i]
        # adjust the interior markers toward their desired positions
        for i in range(1, 4):
            d = self._np[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1) or \
                    (d <= -1.0 and n[i - 1] - n[i] < -1):
                s = 1 if d >= 1.0 else -1
                qp = self._parabolic(i, s)
                if q[i - 1] < qp < q[i + 1]:
                    q[i] = qp
                else:
                    q[i] = q[i] + s * (q[i + s] - q[i]) / (n[i + s] - n[i])
                n[i] += s

    def _parabolic(self, i: int, s: int) -> float:
        q, n = self._q, self._n
        return q[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def value(self) -> float:
        if self.count == 0:
            return 0.0
        if self.count <= 5:
            # exact: numpy's linear-interpolation percentile on <=5 points
            return float(np.percentile(np.array(self._q), self.p * 100.0))
        return self._q[2]


class LatencyStats:
    """Streaming summary of one latency series (see module docstring).

    ``percentile(q)`` is exact (numpy-identical) below ``CUTOVER``
    observations and a P² estimate beyond; only the quantiles in
    :data:`TRACKED_QUANTILES` are available once sketching starts.
    """

    #: raw-buffer size below which percentiles stay exact
    CUTOVER = 64

    __slots__ = ("count", "total", "vmin", "vmax", "_buf", "_sketches",
                 "_cdf")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._buf: Optional[List[float]] = []
        self._sketches: Optional[List[P2Quantile]] = None
        # merged-mode state (see ``merge``): [(count_i, cdf points_i)]
        self._cdf: Optional[List[Tuple[int, List[Tuple[float, float]]]]] \
            = None

    def add(self, x: float) -> None:
        if self._cdf is not None:
            raise RuntimeError("a merged LatencyStats is read-only")
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x
        if self._sketches is None:
            self._buf.append(x)
            if len(self._buf) > self.CUTOVER:
                # switch to sketching: replay the buffer in arrival order
                self._sketches = [P2Quantile(p) for p in TRACKED_QUANTILES]
                for v in self._buf:
                    for sk in self._sketches:
                        sk.add(v)
                self._buf = None
            return
        for sk in self._sketches:
            sk.add(x)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q-th percentile (q in [0, 100]).  Any q while the raw buffer is
        live; only 100*TRACKED_QUANTILES once sketching started; any q
        again on a merged instance (CDF inversion)."""
        if self.count == 0:
            return 0.0
        if self._cdf is not None:
            return self._merged_percentile(q)
        if self._sketches is None:
            return float(np.percentile(np.array(self._buf), q))
        for p, sk in zip(TRACKED_QUANTILES, self._sketches):
            if abs(p * 100.0 - q) < 1e-9:
                return sk.value()
        raise ValueError(
            f"percentile {q} not tracked once sketching starts "
            f"(have {[p * 100 for p in TRACKED_QUANTILES]})")

    # -- export ------------------------------------------------------------
    def to_dict(self) -> dict:
        """Flat JSON-safe digest: exact counters + the tracked quantiles
        (what BENCH records and the metrics registry embed)."""
        return {
            "count": self.count,
            "total": self.total,
            "mean": self.mean,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "quantiles": {f"{p:g}": self.percentile(p * 100.0)
                          for p in TRACKED_QUANTILES} if self.count else {},
        }

    def snapshot(self) -> dict:
        """Full JSON-safe state export: the :meth:`to_dict` digest plus
        the mode-specific internals (raw sample buffer, P² marker sets,
        or merged CDF knots) — everything :meth:`from_snapshot` needs to
        rebuild an instance that answers every query identically."""
        out = self.to_dict()
        if self._cdf is not None:
            out["mode"] = "merged"
            out["cdf"] = [[n, [[v, f] for v, f in pts]]
                          for n, pts in self._cdf]
        elif self._sketches is None:
            out["mode"] = "exact"
            out["samples"] = list(self._buf)
        else:
            out["mode"] = "sketch"
            out["sketches"] = [
                {"p": sk.p, "q": list(sk._q), "n": list(sk._n),
                 "np": list(sk._np), "count": sk.count}
                for sk in self._sketches]
        return out

    @classmethod
    def from_snapshot(cls, snap: dict) -> "LatencyStats":
        """Rebuild an instance from :meth:`snapshot` output.  Exact mode
        replays the buffer in its recorded order (bit-identical counters
        and percentiles); sketch/merged modes restore the marker/CDF
        state directly."""
        out = cls()
        mode = snap.get("mode", "exact")
        if mode == "exact":
            for v in snap.get("samples", ()):
                out.add(v)
            return out
        out.count = int(snap["count"])
        out.total = float(snap["total"])
        out.vmin = float(snap["min"])
        out.vmax = float(snap["max"])
        out._buf = None
        if mode == "merged":
            out._cdf = [(int(n), [(float(v), float(f)) for v, f in pts])
                        for n, pts in snap["cdf"]]
            return out
        if mode != "sketch":
            raise ValueError(f"unknown LatencyStats snapshot mode {mode!r}")
        out._sketches = []
        for s in snap["sketches"]:
            sk = P2Quantile(float(s["p"]))
            sk._q = [float(v) for v in s["q"]]
            sk._n = [int(v) for v in s["n"]]
            sk._np = [float(v) for v in s["np"]]
            sk.count = int(s["count"])
            out._sketches.append(sk)
        return out

    # -- fleet merge -------------------------------------------------------
    def _cdf_points(self) -> List[Tuple[float, float]]:
        """This series' empirical CDF as (value, fraction<=value) knots —
        exact from a live buffer; from the union of all tracked P² marker
        sets (heights at their maintained positions) once sketched."""
        if self._sketches is None:
            b = sorted(self._buf)
            n = len(b)
            return [(v, (i + 1) / n) for i, v in enumerate(b)]
        pts: List[Tuple[float, float]] = []
        for sk in self._sketches:
            denom = max(sk.count - 1, 1)
            pts.extend((h, min(max(pos / denom, 0.0), 1.0))
                       for h, pos in zip(sk._q, sk._n))
        pts.sort()
        out: List[Tuple[float, float]] = []
        frac = 0.0
        for h, fr in pts:               # enforce a monotone CDF
            frac = max(frac, fr)
            out.append((h, frac))
        return out

    @staticmethod
    def _cdf_at(points: List[Tuple[float, float]], x: float) -> float:
        """Piecewise-linear CDF through ``points`` evaluated at ``x``."""
        if x < points[0][0]:
            return 0.0
        if x >= points[-1][0]:
            return 1.0
        heights = [p[0] for p in points]
        i = bisect.bisect_right(heights, x)
        x0, f0 = points[i - 1]
        x1, f1 = points[i]
        if x1 <= x0:
            return f1
        return f0 + (f1 - f0) * (x - x0) / (x1 - x0)

    def _merged_percentile(self, q: float) -> float:
        """Invert the count-weighted mixture CDF by bisection (64
        iterations over [vmin, vmax] — deterministic float arithmetic,
        independent of merge input order)."""
        target = min(max(q / 100.0, 0.0), 1.0)
        if target <= 0.0:
            return self.vmin
        if target >= 1.0:
            return self.vmax
        lo, hi = self.vmin, self.vmax
        for _ in range(64):
            mid = 0.5 * (lo + hi)
            f = sum(n * self._cdf_at(pts, mid) for n, pts in self._cdf) \
                / self.count
            if f < target:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)

    @classmethod
    def merge(cls, parts: Sequence["LatencyStats"]) -> "LatencyStats":
        """Combine per-pod series into one fleet-level summary.

        Exact counters (count/total/min/max) always combine exactly.  When
        every part still holds its raw buffer, the buffers are replayed in
        the given (pod-id) order — below ``CUTOVER`` total that stays
        numpy-exact, beyond it the result is the same sketch one stream
        observing the pods in that order would build.  Once any part has
        switched to sketching, the merge keeps each part's piecewise-linear
        CDF (from its marker sets) and answers percentiles by inverting
        the count-weighted mixture — O(pods) memory, deterministic for a
        fixed part order, and exact in the limit of exact parts.  Merged
        instances are read-only (``add`` raises).
        """
        out = cls()
        live = [p for p in parts if p.count]
        if not live:
            return out
        if all(p._sketches is None for p in live):
            for p in live:
                for v in p._buf:
                    out.add(v)
            return out
        out.count = sum(p.count for p in live)
        out.total = sum(p.total for p in live)
        out.vmin = min(p.vmin for p in live)
        out.vmax = max(p.vmax for p in live)
        out._buf = None
        out._cdf = [(p.count, p._cdf_points()) for p in live]
        return out
