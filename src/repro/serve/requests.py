"""Request-level serving model: per-model profiles and arrival sampling.

A resident LLM tenant is not an opaque blob — it serves a stream of
*requests*, each with a prompt (prefill phase: compute-bound) and a number
of output tokens (decode phase: bandwidth-bound).  This module defines

* :class:`RequestClass` — one request shape in a tenant's mix (chat-style
  short-prompt/long-output vs document-style long-prompt/short-output:
  the prefill/decode-mixed workload the FlexNPU line of work targets);
* :class:`ServeProfile` — everything the serving plane needs to know
  about a served model: KV-cache bytes per token (from the real model
  configs: ``2 * n_layers * n_kv_heads * head_dim * 2 bytes`` — K and V,
  GQA-aware, bf16), the scoring proxy's sequence length, per-tenant
  request rate, batch slots, KV arena geometry, and the TTFT/TPOT SLOs;
* :func:`sample_requests` — a deterministic Poisson request stream over a
  profile's class mix (seeded per tenant, so every policy in a comparison
  serves the *same* requests).

Profiles exist only for the LLM (tensor-parallel) models in the trace
catalogs; CNN tenants keep the frame-throughput model and are invisible to
the serving plane.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One request shape in a model's serving mix.

    Prompt lengths are lognormal (mean ``prompt_mean`` tokens, coefficient
    of variation ``prompt_cv``) clipped to ``[8, prompt_max]`` — or, with
    ``prompt_dist="pareto"``, Pareto-I heavy-tailed with shape
    ``prompt_alpha`` and the same mean (the doc-heavy long-prefill mix);
    output lengths are exponential (mean ``out_mean``) clipped to
    ``[2, out_max]``.
    """
    name: str
    weight: float
    prompt_mean: float
    prompt_cv: float
    prompt_max: int
    out_mean: float
    out_max: int
    prompt_dist: str = "lognormal"       # "lognormal" | "pareto"
    prompt_alpha: float = 2.5            # Pareto shape (tail index)


#: chat: short prompt, long generation — decode-dominant
#: doc:  long prompt, short generation — prefill-dominant
_CHAT = RequestClass("chat", 0.65, prompt_mean=96.0, prompt_cv=0.6,
                     prompt_max=512, out_mean=96.0, out_max=256)
_DOC = RequestClass("doc", 0.35, prompt_mean=768.0, prompt_cv=0.5,
                    prompt_max=2048, out_mean=24.0, out_max=64)

#: doc-heavy long-prefill mix: mostly documents whose lengths are
#: Pareto-distributed (tail index ~2.1: finite mean, huge variance), the
#: heavy-tail regime where a single long prompt can stall a whole batch's
#: decode — the prefill/decode interference case phase-aware schedulers
#: and chunked-prefill papers target
_DOC_HEAVY = (
    RequestClass("chat", 0.35, prompt_mean=96.0, prompt_cv=0.6,
                 prompt_max=512, out_mean=96.0, out_max=256),
    RequestClass("doc", 0.65, prompt_mean=900.0, prompt_cv=0.5,
                 prompt_max=4096, out_mean=24.0, out_max=64,
                 prompt_dist="pareto", prompt_alpha=2.1),
)

#: named request mixes selectable per run (None = the profile's own mix)
REQUEST_MIXES: Dict[str, Optional[Tuple[RequestClass, ...]]] = {
    "default": None,
    "doc_heavy": _DOC_HEAVY,
}


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """The shape of a tenant's request-arrival intensity over time.

    ``rate_at(t, base)`` is the instantaneous arrival rate (requests/s) at
    ``t`` seconds after tenant arrival, where ``base`` is the profile's
    (possibly scaled) mean rate:

    * ``poisson`` — homogeneous: ``base`` everywhere (the legacy stream);
    * ``diurnal`` — sinusoidal load curve with period ``period_s`` and
      relative swing ``amplitude`` (peak = ``base * (1 + amplitude)``);
    * ``flash`` — flash crowd: ``base`` except a ``flash_mult`` x burst on
      ``[flash_t_s, flash_t_s + flash_dur_s)``.

    Inhomogeneous streams are sampled by thinning: propose at
    ``max_rate``, accept with probability ``rate_at / max_rate``.
    """
    kind: str = "poisson"                # "poisson" | "diurnal" | "flash"
    period_s: float = 240.0
    amplitude: float = 0.6
    flash_t_s: float = 45.0
    flash_dur_s: float = 25.0
    flash_mult: float = 4.0

    KINDS = ("poisson", "diurnal", "flash")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(
                f"arrival kind must be one of {self.KINDS}, "
                f"got {self.kind!r}")

    def rate_at(self, t, base: float):
        """Instantaneous rate at ``t`` (scalar or ndarray, vectorized)."""
        if self.kind == "poisson":
            return base * np.ones_like(np.asarray(t, dtype=float))
        if self.kind == "diurnal":
            return base * (1.0 + self.amplitude
                           * np.sin(2.0 * math.pi
                                    * np.asarray(t, dtype=float)
                                    / self.period_s))
        in_burst = ((np.asarray(t, dtype=float) >= self.flash_t_s)
                    & (np.asarray(t, dtype=float)
                       < self.flash_t_s + self.flash_dur_s))
        return base * np.where(in_burst, self.flash_mult, 1.0)

    def max_rate(self, base: float) -> float:
        if self.kind == "diurnal":
            return base * (1.0 + self.amplitude)
        if self.kind == "flash":
            return base * self.flash_mult
        return base


@dataclasses.dataclass(frozen=True)
class ServeProfile:
    """Serving parameters of one model (see module docstring).

    ``kv_bytes_per_token`` is the K+V footprint of one token across all
    layers; ``proxy_seq`` is the sequence length the scoring proxy graph
    was built at (one simulator "iteration" is a full forward pass over
    that many tokens, so prefill throughput is ``fps * proxy_seq``).
    ``kv_arena_bytes``/``kv_block_bytes`` size the tenant's KV buddy arena
    (powers of two; each block becomes one RTT range).  ``ttft_slo_s`` /
    ``tpot_slo_s`` define SLA-goodput: a request is *good* when its
    time-to-first-token and time-per-output-token both meet target.
    """
    model: str
    kv_bytes_per_token: int
    proxy_seq: int
    rate_per_s: float
    max_batch: int
    kv_arena_bytes: int
    kv_block_bytes: int
    ttft_slo_s: float
    tpot_slo_s: float
    classes: Tuple[RequestClass, ...] = (_CHAT, _DOC)


def _kv_bpt(n_layers: int, n_kv_heads: int, head_dim: int) -> int:
    """K+V bytes per token: 2 tensors x layers x kv heads x head dim x bf16."""
    return 2 * n_layers * n_kv_heads * head_dim * 2


# KV geometry from the real configs (repro/configs/: n_layers, n_kv_heads,
# head_dim) for the config-proxy models, and full-MHA (n_heads == n_kv_heads,
# head_dim = d_model / n_heads) for the registry transformer workloads.
# proxy_seq mirrors sched/traces._CONFIG_PROXIES and the workload registry
# defaults — it must match the graph get_serving_workload() returns.
# Rates and SLOs are calibrated against the analytic phase model on the
# SIM config (see DESIGN.md "Serving plane"): per-tenant token demand sits
# at 50–90% of a lone tenant's decode capacity, so Poisson bursts and
# multi-tenant HBM sharing push queues over the resize thresholds without
# drowning the mesh; KV arenas hold ~60–80% of a full batch at max
# context, so long-context mixes hit real buddy OOM (admission deferral +
# preempt-recompute).  TPOT targets are meetable at moderate co-residency
# (a handful of HBM streamers) and busted under TDM slicing / UVM
# global-memory sync — the axis the SLA-goodput gate compares.
SERVE_PROFILES: Dict[str, ServeProfile] = {
    "qwen2_0_5b": ServeProfile(
        model="qwen2_0_5b",
        kv_bytes_per_token=_kv_bpt(24, 2, 64),          # 12 KiB
        proxy_seq=512, rate_per_s=8.0, max_batch=8,
        kv_arena_bytes=64 << 20, kv_block_bytes=2 << 20,
        ttft_slo_s=0.8, tpot_slo_s=0.03),
    "llama3_2_1b": ServeProfile(
        model="llama3_2_1b",
        kv_bytes_per_token=_kv_bpt(16, 8, 64),          # 32 KiB
        proxy_seq=512, rate_per_s=3.0, max_batch=8,
        kv_arena_bytes=128 << 20, kv_block_bytes=2 << 20,
        ttft_slo_s=1.2, tpot_slo_s=0.05),
    "qwen2_7b": ServeProfile(
        model="qwen2_7b",
        kv_bytes_per_token=_kv_bpt(28, 4, 128),         # 56 KiB
        proxy_seq=256, rate_per_s=1.2, max_batch=4,
        kv_arena_bytes=256 << 20, kv_block_bytes=4 << 20,
        ttft_slo_s=3.0, tpot_slo_s=0.25),
    "gpt2_small": ServeProfile(
        model="gpt2_small",
        kv_bytes_per_token=_kv_bpt(12, 12, 64),         # 36 KiB, MHA
        proxy_seq=1024, rate_per_s=6.0, max_batch=8,
        kv_arena_bytes=128 << 20, kv_block_bytes=2 << 20,
        ttft_slo_s=0.8, tpot_slo_s=0.025),
    "gpt2_medium": ServeProfile(
        model="gpt2_medium",
        kv_bytes_per_token=_kv_bpt(24, 16, 64),         # 96 KiB, MHA
        proxy_seq=1024, rate_per_s=4.0, max_batch=8,
        kv_arena_bytes=256 << 20, kv_block_bytes=2 << 20,
        ttft_slo_s=1.5, tpot_slo_s=0.05),
    "transformer": ServeProfile(
        model="transformer",
        kv_bytes_per_token=_kv_bpt(6, 8, 64),           # 12 KiB, MHA
        proxy_seq=512, rate_per_s=15.0, max_batch=8,
        kv_arena_bytes=64 << 20, kv_block_bytes=1 << 20,
        ttft_slo_s=0.4, tpot_slo_s=0.012),
}


def get_profile(model: str) -> Optional[ServeProfile]:
    """The model's serving profile, or None for non-LLM (frame) tenants."""
    return SERVE_PROFILES.get(model)


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One sampled request: arrives ``t_s`` seconds after tenant admission
    with a ``prompt_tokens``-token prompt and ``max_new_tokens`` to decode
    (the first of which is produced by the prefill pass, like
    :class:`~repro.serve.engine.ServeEngine`)."""
    rid: int
    t_s: float
    prompt_tokens: int
    max_new_tokens: int
    cls: str


def _resolve_mix(profile: ServeProfile,
                 mix: str) -> Tuple[RequestClass, ...]:
    if mix not in REQUEST_MIXES:
        raise ValueError(f"unknown request mix {mix!r}; "
                         f"have {sorted(REQUEST_MIXES)}")
    classes = REQUEST_MIXES[mix]
    return profile.classes if classes is None else classes


def sample_requests(profile: ServeProfile, horizon_s: float, seed: int,
                    arrival: Optional[ArrivalProcess] = None,
                    rate_scale: float = 1.0,
                    mix: str = "default") -> List[RequestSpec]:
    """Deterministic request stream over ``[0, horizon_s)``.

    Seeded per tenant (the serving plane passes ``hash(trace seed, tid)``),
    so the same tenant serves the same requests under every policy —
    request-level trajectories are comparable across policies and
    bit-reproducible across runs.

    The historical configuration (homogeneous Poisson, ``rate_scale=1``,
    the profile's own class mix) goes through the original draw-for-draw
    scalar loop, so pre-existing streams are bit-identical.  Everything
    else — inhomogeneous arrivals (thinning at ``max_rate``), scaled
    rates, alternate mixes — is sampled by the chunked numpy path (still
    deterministic per seed, but a different draw order).
    """
    rng = np.random.default_rng(seed)
    classes = _resolve_mix(profile, mix)
    base = profile.rate_per_s * rate_scale
    legacy = ((arrival is None or arrival.kind == "poisson")
              and rate_scale == 1.0 and mix == "default")
    if legacy:
        return _sample_legacy(rng, profile, horizon_s)
    return _sample_batch(rng, classes, horizon_s, base,
                         arrival or ArrivalProcess())


def _sample_legacy(rng: np.random.Generator, profile: ServeProfile,
                   horizon_s: float) -> List[RequestSpec]:
    """The original scalar Poisson loop — draw order is load-bearing (the
    serving gates pin trajectories built on these exact streams)."""
    weights = np.array([c.weight for c in profile.classes], float)
    weights /= weights.sum()
    out: List[RequestSpec] = []
    t = 0.0
    rid = 0
    while True:
        t += float(rng.exponential(1.0 / max(profile.rate_per_s, 1e-9)))
        if t >= horizon_s:
            return out
        cls = profile.classes[int(rng.choice(len(profile.classes),
                                             p=weights))]
        # lognormal with the class's mean/cv in token space
        sigma2 = math.log(1.0 + cls.prompt_cv ** 2)
        mu = math.log(max(cls.prompt_mean, 1.0)) - sigma2 / 2.0
        prompt = int(np.clip(rng.lognormal(mu, math.sqrt(sigma2)),
                             8, cls.prompt_max))
        new = int(np.clip(rng.exponential(cls.out_mean), 2, cls.out_max))
        out.append(RequestSpec(rid=rid, t_s=t, prompt_tokens=prompt,
                               max_new_tokens=new, cls=cls.name))
        rid += 1


def _sample_batch(rng: np.random.Generator,
                  classes: Tuple[RequestClass, ...], horizon_s: float,
                  base: float, arrival: ArrivalProcess) -> List[RequestSpec]:
    """Chunked numpy sampler: thinning for inhomogeneous rates, per-class
    vectorized length draws.  O(requests) with ~10 rng calls per tenant
    instead of ~5 per request — what makes million-request traces cheap
    to *sample*, not just to serve."""
    mx = max(arrival.max_rate(base), 1e-9)
    chunks: List[np.ndarray] = []
    t = 0.0
    # first chunk sized to the expected count; top-ups are small
    size = max(256, int(mx * horizon_s * 1.25) + 16)
    while t < horizon_s:
        gaps = rng.exponential(1.0 / mx, size=size)
        ts = t + np.cumsum(gaps)
        u = rng.random(size=size)
        keep = (u * mx <= arrival.rate_at(ts, base)) & (ts < horizon_s)
        chunks.append(ts[keep])
        t = float(ts[-1])
        size = 256
    ts = np.concatenate(chunks) if chunks else np.empty(0)
    n = len(ts)
    if n == 0:
        return []
    weights = np.array([c.weight for c in classes], float)
    weights /= weights.sum()
    ci = rng.choice(len(classes), size=n, p=weights)
    prompts = np.empty(n, dtype=np.int64)
    news = np.empty(n, dtype=np.int64)
    for i, cls in enumerate(classes):
        m = ci == i
        k = int(m.sum())
        if not k:
            continue
        if cls.prompt_dist == "pareto":
            # Pareto-I with the class mean: x_m * (1 + Lomax(alpha))
            a = cls.prompt_alpha
            xm = cls.prompt_mean * (a - 1.0) / a
            draw = xm * (1.0 + rng.pareto(a, size=k))
        else:
            sigma2 = math.log(1.0 + cls.prompt_cv ** 2)
            mu = math.log(max(cls.prompt_mean, 1.0)) - sigma2 / 2.0
            draw = rng.lognormal(mu, math.sqrt(sigma2), size=k)
        prompts[m] = np.clip(draw, 8, cls.prompt_max).astype(np.int64)
        news[m] = np.clip(rng.exponential(cls.out_mean, size=k),
                          2, cls.out_max).astype(np.int64)
    names = [c.name for c in classes]
    return [RequestSpec(rid=i, t_s=float(ts[i]),
                        prompt_tokens=int(prompts[i]),
                        max_new_tokens=int(news[i]), cls=names[int(ci[i])])
            for i in range(n)]
