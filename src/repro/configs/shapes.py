"""The four assigned input shapes, per LM architecture.

  train_4k     seq_len=4096    global_batch=256   (training;    train_step)
  prefill_32k  seq_len=32768   global_batch=32    (inference;   prefill_step)
  decode_32k   seq_len=32768   global_batch=128   (decode: one new token with
                                                   a KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     (long-context decode; only
                                                   sub-quadratic archs)

``applicable()`` implements the assignment's skip rules: long_500k requires
sub-quadratic attention (SSM/hybrid/linear); full-attention archs skip it
(documented in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def is_subquadratic(cfg: ModelConfig) -> bool:
    if cfg.family == "ssm":
        return True
    if cfg.family == "hybrid" and cfg.sliding_window > 0:
        return True
    return False


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, "SKIP(full-attn): quadratic attention at 524k context"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    from .base import ARCH_IDS
    return [(a, s) for a in ARCH_IDS for s in SHAPE_ORDER]
