from .base import ModelConfig, ARCH_IDS, ALIASES, get_config, registry
from .shapes import SHAPES, SHAPE_ORDER, ShapeSpec, applicable, all_cells, is_subquadratic
