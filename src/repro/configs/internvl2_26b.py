"""InternVL2-26B — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92553.  The ViT
frontend is a STUB: input_specs() provides precomputed, projected patch
embeddings (B, 256, 6144) that are concatenated ahead of the text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    frontend="vision_stub", frontend_seq=256, frontend_dim=6144,
    source="arXiv:2404.16821; hf",
)
