"""Whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32 decoder layers (and 32 encoder layers), d_model=1280, 20 heads (MHA,
kv=20), d_ff=5120, vocab=51866.  The conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 1280).  LayerNorm + GELU.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_large_v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    n_enc_layers=32, enc_seq=1500,
    frontend="audio_stub", frontend_seq=1500, frontend_dim=1280,
    norm="layernorm", mlp="gelu",
    source="arXiv:2212.04356; unverified",
)
