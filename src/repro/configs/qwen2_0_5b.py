"""Qwen2-0.5B — GQA with QKV bias [arXiv:2407.10671; hf].

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151936.  This is the
paper's own motivating 'small tenant' (§2.2 cites Qwen2-0.5B).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_0_5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64, qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)
