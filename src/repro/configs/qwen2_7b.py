"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671; hf].

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128, qkv_bias=True,
    source="arXiv:2407.10671; hf",
)
