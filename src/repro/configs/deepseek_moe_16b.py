"""DeepSeekMoE-16B — fine-grained MoE [arXiv:2401.06066; hf].

28L, d_model=2048, 16 heads (kv=16), vocab=102400.  2 shared + 64 routed
experts, top-6, expert hidden 1408; first layer uses a dense FFN (10944).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_moe_16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_k_dense=1,
    source="arXiv:2401.06066; hf",
)
