"""Model/arch configuration system.

One dataclass covers every assigned architecture family (dense / MoE / SSM /
hybrid / enc-dec / VLM); per-arch modules under ``repro/configs/`` fill in
the exact published numbers.  ``registry()`` exposes them to the launcher
(``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    mlp: str = "swiglu"           # swiglu | gelu
    causal: bool = True

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0             # per-expert hidden (fine-grained MoE)
    first_k_dense: int = 0        # leading layers with dense FFN (deepseek)
    moe_interleave: int = 1       # MoE every k-th layer (llama4: 2)
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (hymba): parallel attn+ssm heads ---
    sliding_window: int = 0       # 0 = full attention

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0              # encoder frames (stub frontend output)

    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # None | audio_stub | vision_stub
    frontend_seq: int = 0           # patch/frame embeddings per sample
    frontend_dim: int = 0           # embedding width delivered by the stub

    # --- parallelism ---
    attn_shard: str = "auto"   # auto | heads | seq | replicated

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- citation ---
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for 16-way tensor sharding (MaxText-style)."""
        return round_up(self.vocab_size, 256)

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_headdim

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    def attention_layers(self) -> int:
        return 0 if self.family == "ssm" else self.n_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, L = self.d_model, self.n_layers
        hd, H, KV = self.head_dim_, self.n_heads, self.n_kv_heads
        n = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.family == "ssm":
            attn = 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * ns + nh) + di * d + 3 * nh
        if self.family == "moe":
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            routed = self.n_experts * 3 * d * self.moe_d_ff
            router = d * self.n_experts
            dense_ff = 3 * d * self.d_ff
            n_moe, n_dense = self.moe_layer_split()
            n += n_moe * (attn + shared + routed + router)
            n += n_dense * (attn + dense_ff)
            return n
        ff = 3 * d * self.d_ff if self.mlp == "swiglu" else 2 * d * self.d_ff
        if self.family == "ssm":
            per_layer = ssm
        elif self.family == "hybrid":
            per_layer = attn + ssm + ff
        else:
            per_layer = attn + ff
        n += L * per_layer
        if self.n_enc_layers:
            n += self.n_enc_layers * (attn + ff)     # encoder stack
            n += self.n_layers * (attn := attn)      # cross-attn in decoder
            n += self.n_layers * (d * H * hd + 2 * d * KV * hd + H * hd * d)
        return n

    def moe_layer_split(self) -> Tuple[int, int]:
        """(n_moe_layers, n_dense_layers) after first_k_dense + interleave."""
        if self.family != "moe":
            return (0, self.n_layers)
        rest = self.n_layers - self.first_k_dense
        n_moe = rest // self.moe_interleave
        return (n_moe, self.n_layers - n_moe)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: shared + top_k routed)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd, H, KV = self.head_dim_, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        n = 2 * self.padded_vocab * d
        active_ff = (self.n_shared_experts + self.top_k) * 3 * d * self.moe_d_ff
        n_moe, n_dense = self.moe_layer_split()
        n += n_moe * (attn + active_ff + d * self.n_experts)
        n += n_dense * (attn + 3 * d * self.d_ff)
        return n


def reduce_for_smoke(cfg: "ModelConfig") -> "ModelConfig":
    """Same family/structure, laptop-sized: few layers, narrow width, tiny
    vocab, few experts — used by the per-arch CPU smoke tests."""
    kw: Dict = dict(
        n_layers=max(2, cfg.moe_interleave * (2 if cfg.first_k_dense == 0 else 2) if cfg.family == "moe" else 2),
        d_model=64,
        d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        vocab_size=256,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16)
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32,
                  first_k_dense=min(cfg.first_k_dense, 1),
                  n_layers=2 * cfg.moe_interleave + cfg.first_k_dense)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_headdim=8, ssm_state=8, ssm_chunk=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq=24, frontend_seq=24, frontend_dim=64)
    if cfg.family == "vlm":
        kw.update(frontend_seq=8, frontend_dim=64)
    return dataclasses.replace(cfg, **kw)


ARCH_IDS = [
    "hymba_1_5b",
    "qwen2_7b",
    "llama3_2_1b",
    "qwen2_0_5b",
    "qwen3_4b",
    "mamba2_1_3b",
    "whisper_large_v3",
    "deepseek_moe_16b",
    "llama4_maverick_400b_a17b",
    "internvl2_26b",
]

# accept both dash and underscore spellings on the CLI
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-7b": "qwen2_7b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-4b": "qwen3_4b",
    "mamba2-1.3b": "mamba2_1_3b",
    "whisper-large-v3": "whisper_large_v3",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "internvl2-26b": "internvl2_26b",
})


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def registry() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
