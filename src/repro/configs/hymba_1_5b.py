"""Hymba-1.5B — hybrid parallel attention+Mamba heads [arXiv:2411.13676; hf].

32L, d_model=1600, 25 heads (GQA kv=5), d_ff=5504, vocab=32001, ssm_state=16.
Sliding-window attention (most layers in the paper use SWA-1024; we use SWA
everywhere — meta-tokens and the 3 global-attention layers are omitted, see
DESIGN.md §Arch-applicability) keeps it sub-quadratic, so long_500k runs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1_5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_headdim=50,   # d_inner=3200 -> 64 SSM heads
    sliding_window=1024,
    source="arXiv:2411.13676; hf",
)
