"""Qwen3-4B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B; hf].

36L, d_model=2560, 32 heads (GQA kv=8), d_ff=9728, vocab=151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128, qk_norm=True,
    source="hf:Qwen/Qwen3-8B; hf",
)
