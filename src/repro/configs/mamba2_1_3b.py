"""Mamba2-1.3B — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L, d_model=2048, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*2048 = 4096, headdim 64 -> 64 SSD heads.  Sub-quadratic: runs
long_500k; decode state is constant-size (no KV growth).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_1_3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
