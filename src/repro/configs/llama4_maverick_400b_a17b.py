"""Llama-4 Maverick 400B-A17B — MoE top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model=5120, 40 heads (GQA kv=8), vocab=202048.  128 routed experts
top-1 + 1 shared expert, expert hidden 8192; MoE on alternating layers
(interleave=2) which lands total params ~400B with ~17B active.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b_a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    n_experts=128, n_shared_experts=1, top_k=1, moe_d_ff=8192,
    first_k_dense=0, moe_interleave=2,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
