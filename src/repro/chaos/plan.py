"""Seeded, correlated fault plans for the chaos plane.

A :class:`FaultPlan` is a deterministic, replayable storm: a time-ordered
tuple of :class:`FaultEvent` records describing *correlated* disturbances
on a ``rows x cols`` NPU mesh —

* **spatial core bursts** — a whole mesh neighborhood dies at once (a
  power-domain or column-driver fault takes physically adjacent cores
  together), repaired as a unit after an exponential repair delay;
* **directed NoC-link outages** (``link-fail``) — traffic crossing the
  edge is re-costed at :data:`LINK_FAIL_FACTOR` x its bytes until repair;
* **NoC-link stragglers** (``link-degrade``) — a slow link at a sampled
  bandwidth-degradation factor (flaky SerDes, thermal throttling);
* **switch brownouts** and **whole-pod loss** — fleet-scope events the
  fleet driver turns into :class:`~repro.fleet.fleet.Scenario`\\ s.

Everything derives from ``numpy.random.default_rng([seed, 0xC4A05])``:
the same ``(rows, cols, horizon_s, seed, profile)`` always yields the
bit-identical plan, which is what the chaos gate replays.

The plan is consumer-agnostic: :meth:`FaultPlan.cluster_events` feeds
``ClusterScheduler.inject_chaos`` (duck-typed on ``kind / t_s / cores /
link / factor`` — this module imports nothing from :mod:`repro.sched`),
and :meth:`FaultPlan.fleet_events` covers the pod/switch scope.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

# re-cost factor for a *failed* (not merely degraded) directed link:
# traffic that still crosses it behaves as if the link carried this many
# times its actual bytes (retransmit storms over the dead lane pair)
LINK_FAIL_FACTOR = 16.0

# core-burst kinds arrive paired: every burst schedules its repair
CLUSTER_KINDS = frozenset({
    "core-fail", "core-repair", "link-fail", "link-degrade", "link-repair"})
FLEET_KINDS = frozenset({"pod-fail", "switch-brownout"})


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One disturbance (or its repair) at ``t_s`` seconds.

    ``cores`` carries core-burst membership, ``link`` a directed NoC edge
    ``(u, v)``, ``factor`` the bandwidth-degradation multiplier (>= 1;
    :data:`LINK_FAIL_FACTOR` for hard link outages, the brownout slowdown
    for ``switch-brownout``), ``pod_id`` the fleet scope and
    ``duration_s`` the fleet-event length."""
    t_s: float
    kind: str
    cores: Tuple[int, ...] = ()
    link: Optional[Tuple[int, int]] = None
    factor: float = 1.0
    pod_id: Optional[int] = None
    duration_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class StormProfile:
    """Intensity knobs for :func:`make_fault_plan` (all rates per second)."""
    burst_rate: float            # spatial core-burst arrival rate
    burst_size_mean: float       # mean cores per burst (geometric)
    core_repair_mean_s: float    # exponential burst-repair delay
    link_fail_rate: float        # hard directed-link outages
    link_degrade_rate: float     # straggler (slow-link) events
    degrade_lo: float            # straggler factor range [lo, hi)
    degrade_hi: float
    link_repair_mean_s: float    # exponential link-repair delay
    pod_fail_rate: float = 0.0   # fleet scope: whole-pod loss
    brownout_rate: float = 0.0   # fleet scope: switch brownouts
    brownout_factor: float = 4.0
    brownout_mean_s: float = 5.0


STORMS: Dict[str, StormProfile] = {
    # the gate storm: a few correlated bursts and link faults per minute,
    # repairs on the tens-of-seconds scale — heavy enough to force kills,
    # light enough that availability floors are meaningful
    "storm": StormProfile(
        burst_rate=1 / 12.0, burst_size_mean=3.0, core_repair_mean_s=18.0,
        link_fail_rate=1 / 25.0, link_degrade_rate=1 / 15.0,
        degrade_lo=1.5, degrade_hi=4.0, link_repair_mean_s=12.0,
        pod_fail_rate=1 / 120.0, brownout_rate=1 / 60.0),
    # background-noise profile for long soak runs
    "drizzle": StormProfile(
        burst_rate=1 / 60.0, burst_size_mean=1.5, core_repair_mean_s=10.0,
        link_fail_rate=1 / 120.0, link_degrade_rate=1 / 45.0,
        degrade_lo=1.2, degrade_hi=2.5, link_repair_mean_s=8.0),
}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic storm over a ``rows x cols`` mesh."""
    name: str
    seed: int
    rows: int
    cols: int
    horizon_s: float
    events: Tuple[FaultEvent, ...]

    def cluster_events(self) -> Tuple[FaultEvent, ...]:
        """Core/link-scope events, for ``ClusterScheduler.inject_chaos``."""
        return tuple(e for e in self.events if e.kind in CLUSTER_KINDS)

    def fleet_events(self) -> Tuple[FaultEvent, ...]:
        """Pod/switch-scope events, for the fleet driver."""
        return tuple(e for e in self.events if e.kind in FLEET_KINDS)

    def summary(self) -> Dict[str, int]:
        """Event counts per kind (deterministic key order)."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return dict(sorted(out.items()))


def _burst_cores(center: int, size: int, rows: int, cols: int) -> Tuple[int, ...]:
    """The ``size`` cores nearest ``center`` on a row-major mesh, expanding
    by Manhattan distance (ties broken by core id) — a spatially-correlated
    failure neighborhood."""
    r0, c0 = divmod(center, cols)
    ranked = sorted(range(rows * cols),
                    key=lambda n: (abs(n // cols - r0) + abs(n % cols - c0), n))
    return tuple(sorted(ranked[:size]))


def _mesh_neighbor(core: int, rows: int, cols: int, pick: float) -> int:
    """A deterministic mesh neighbor of ``core`` chosen by ``pick`` in
    [0, 1) over the sorted neighbor list."""
    r, c = divmod(core, cols)
    nbrs = []
    if r > 0:
        nbrs.append((r - 1) * cols + c)
    if r + 1 < rows:
        nbrs.append((r + 1) * cols + c)
    if c > 0:
        nbrs.append(r * cols + c - 1)
    if c + 1 < cols:
        nbrs.append(r * cols + c + 1)
    return nbrs[min(int(pick * len(nbrs)), len(nbrs) - 1)]


def _arrival_times(rng: np.random.Generator, rate: float,
                   horizon_s: float) -> List[float]:
    """Poisson-process arrival instants in (0, horizon_s)."""
    out: List[float] = []
    if rate <= 0.0:
        return out
    t = float(rng.exponential(1.0 / rate))
    while t < horizon_s:
        out.append(t)
        t += float(rng.exponential(1.0 / rate))
    return out


def make_fault_plan(rows: int, cols: int, horizon_s: float, seed: int = 0,
                    profile: str = "storm", n_pods: int = 0) -> FaultPlan:
    """Build the deterministic storm for one mesh.

    Repairs are scheduled per fault (exponential delays); a repair that
    would land past ``horizon_s`` is dropped — that fault stays down to
    the end of the run and its downtime is closed at the horizon.  Pass
    ``n_pods > 0`` to also draw fleet-scope pod-loss / switch-brownout
    events from the profile's fleet rates.
    """
    try:
        prof = STORMS[profile]
    except KeyError:
        raise KeyError(f"unknown storm profile {profile!r}; "
                       f"have {sorted(STORMS)}")
    rng = np.random.default_rng([int(seed), 0xC4A05])
    n_cores = rows * cols
    events: List[FaultEvent] = []

    # -- spatial core bursts (fail + paired whole-burst repair) ----------
    for t in _arrival_times(rng, prof.burst_rate, horizon_s):
        center = int(rng.integers(n_cores))
        size = min(1 + int(rng.geometric(1.0 / prof.burst_size_mean)),
                   max(n_cores // 4, 1))
        cores = _burst_cores(center, size, rows, cols)
        events.append(FaultEvent(t_s=t, kind="core-fail", cores=cores))
        t_rep = t + float(rng.exponential(prof.core_repair_mean_s))
        if t_rep < horizon_s:
            events.append(FaultEvent(t_s=t_rep, kind="core-repair",
                                     cores=cores))

    # -- directed NoC-link outages and stragglers ------------------------
    for kind, rate in (("link-fail", prof.link_fail_rate),
                       ("link-degrade", prof.link_degrade_rate)):
        for t in _arrival_times(rng, rate, horizon_s):
            u = int(rng.integers(n_cores))
            v = _mesh_neighbor(u, rows, cols, float(rng.random()))
            if kind == "link-fail":
                factor = LINK_FAIL_FACTOR
            else:
                factor = float(rng.uniform(prof.degrade_lo, prof.degrade_hi))
            events.append(FaultEvent(t_s=t, kind=kind, link=(u, v),
                                     factor=factor))
            t_rep = t + float(rng.exponential(prof.link_repair_mean_s))
            if t_rep < horizon_s:
                events.append(FaultEvent(t_s=t_rep, kind="link-repair",
                                         link=(u, v)))

    # -- fleet scope: whole-pod loss and switch brownouts ----------------
    if n_pods > 0:
        for t in _arrival_times(rng, prof.pod_fail_rate, horizon_s):
            events.append(FaultEvent(t_s=t, kind="pod-fail",
                                     pod_id=int(rng.integers(n_pods))))
        for t in _arrival_times(rng, prof.brownout_rate, horizon_s):
            events.append(FaultEvent(
                t_s=t, kind="switch-brownout", factor=prof.brownout_factor,
                duration_s=float(rng.exponential(prof.brownout_mean_s))))

    events.sort(key=lambda e: (e.t_s, e.kind, e.cores,
                               e.link or (), e.pod_id or 0))
    return FaultPlan(name=profile, seed=int(seed), rows=rows, cols=cols,
                     horizon_s=float(horizon_s), events=tuple(events))
