"""Chaos plane: seeded correlated fault injection and storm profiles.

See :mod:`repro.chaos.plan` for the plan generator; the scheduler side of
recovery (repairs, degraded links, checkpoint-resume, retry queue) lives
in :mod:`repro.sched.cluster` and the fleet side in :mod:`repro.fleet`.
"""
from .plan import (  # noqa: F401
    CLUSTER_KINDS,
    FLEET_KINDS,
    LINK_FAIL_FACTOR,
    FaultEvent,
    FaultPlan,
    STORMS,
    StormProfile,
    make_fault_plan,
)

__all__ = [
    "CLUSTER_KINDS",
    "FLEET_KINDS",
    "LINK_FAIL_FACTOR",
    "FaultEvent",
    "FaultPlan",
    "STORMS",
    "StormProfile",
    "make_fault_plan",
]
