from .analysis import (RooflineTerms, collective_bytes,
                       collective_bytes_while_aware, cost_analysis_dict,
                       model_flops_for, PEAK_FLOPS, HBM_BW, ICI_BW)
