"""Analytic, implementation-faithful FLOP and HBM-byte model per cell.

Why analytic: XLA's cost_analysis counts while-loop (lax.scan) bodies once,
and fully unrolling 48-layer x 128-chunk scans to calibrate it is
prohibitive on this 1-core container (measured).  The step functions are
closed-form op graphs, so we count them *exactly as implemented*:

  * attention (train/prefill): the chunked jnp path evaluates every
    (q, kv) block — causal masking does NOT skip work — so the count is the
    full S^2 term.  ``kernelized=True`` halves it (the Pallas flash kernel
    skips masked blocks); that delta is a §Perf lever, not the baseline.
  * remat: scanned blocks run forward twice (fwd + recompute) + backward
    (2x fwd)  =>  train multiplier 4x forward.
  * MoE: capacity-padded routed tokens (T*top_k*capacity_factor), + shared
    experts + router, matching the EP shard_map implementation.
  * bytes: a *kernelized TPU memory model* — params/grads/optimizer traffic,
    per-layer saved activations (remat boundaries), flash-style streaming
    attention (scores never round-trip HBM), KV-cache reads for decode.

Cross-validation: tests/test_roofline.py checks the analytic FLOPs against
XLA cost_analysis on small unrolled dense cells (within tolerance); the
dry-run records both where calibration is available.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec

DT = 2      # bf16 bytes
F32 = 4


def _attn_core_flops(B: int, Sq: int, Skv: int, H: int, hd: int,
                     window: int = 0, kernelized: bool = False) -> float:
    """scores + AV matmuls.  Full-S^2 for the jnp chunked path."""
    if window:
        band = min(window + 512, Skv)   # banded gather width (cq=512)
        eff = band
    else:
        eff = Skv / 2 if kernelized else Skv
    return 2.0 * 2.0 * B * H * Sq * eff * hd


def _ssd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Projections + conv + chunked SSD core (per layer)."""
    d, di = cfg.d_model, cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    q = min(cfg.ssm_chunk, S)
    proj = 2.0 * B * S * d * (2 * di + 2 * N + H)      # z, x, B, C, dt
    outp = 2.0 * B * S * di * d
    conv = 2.0 * B * S * (di + 2 * N) * cfg.ssm_conv_width
    scores = 2.0 * B * S * q * N                        # C B^T per chunk
    y_diag = 2.0 * B * S * q * H * P                    # (L*scores) @ xdt
    y_off = 2.0 * B * S * H * P * N
    state = 2.0 * B * S * H * P * N
    return proj + outp + conv + scores + y_diag + y_off + state


def _layer_flops_full(cfg: ModelConfig, B: int, S: int, kind: str,
                      kernelized: bool) -> float:
    """One layer, full-sequence forward."""
    d, hd = cfg.d_model, cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    f = 0.0
    if kind in ("dense", "moe", "hybrid", "encoder", "decoder"):
        f += 2.0 * B * S * d * (H * hd + 2 * KV * hd)        # qkv
        f += 2.0 * B * S * H * hd * d                        # out proj
        f += _attn_core_flops(B, S, S, H, hd,
                              window=cfg.sliding_window, kernelized=kernelized)
    if kind == "decoder":  # whisper cross-attention
        f += 2.0 * B * S * d * H * hd + 2.0 * B * cfg.enc_seq * d * 2 * KV * hd
        f += 2.0 * B * S * H * hd * d
        f += _attn_core_flops(B, S, cfg.enc_seq, H, hd)
    if kind in ("ssm", "hybrid"):
        f += _ssd_flops(cfg, B, S)
    if kind in ("dense", "hybrid", "encoder", "decoder"):
        n_mats = 3 if cfg.mlp == "swiglu" else 2
        f += 2.0 * B * S * d * cfg.d_ff * n_mats
    if kind == "moe":
        T = B * S
        f += 2.0 * T * d * cfg.n_experts                      # router
        routed_tok = T * cfg.top_k * cfg.capacity_factor      # capacity pad
        f += 2.0 * routed_tok * d * cfg.moe_d_ff * 3
        f += 2.0 * T * d * (cfg.n_shared_experts * cfg.moe_d_ff) * 3
    return f


def _layer_flops_decode(cfg: ModelConfig, B: int, S_cache: int,
                        kind: str) -> float:
    d, hd = cfg.d_model, cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    f = 0.0
    if kind in ("dense", "moe", "hybrid", "decoder"):
        f += 2.0 * B * d * (H * hd + 2 * KV * hd) + 2.0 * B * H * hd * d
        eff = min(S_cache, cfg.sliding_window) if cfg.sliding_window else S_cache
        f += 2.0 * 2.0 * B * H * eff * hd
    if kind == "decoder":
        f += 2.0 * B * d * H * hd + 2.0 * B * H * hd * d
        f += 2.0 * 2.0 * B * H * cfg.enc_seq * hd
    if kind in ("ssm", "hybrid"):
        di, P, N, Hs = cfg.d_inner, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_heads
        f += 2.0 * B * d * (2 * di + 2 * N + Hs) + 2.0 * B * di * d
        f += 2.0 * B * Hs * P * N * 2
    if kind in ("dense", "hybrid", "decoder"):
        n_mats = 3 if cfg.mlp == "swiglu" else 2
        f += 2.0 * B * d * cfg.d_ff * n_mats
    if kind == "moe":
        f += 2.0 * B * d * cfg.n_experts
        f += 2.0 * B * cfg.top_k * d * cfg.moe_d_ff * 3
        f += 2.0 * B * d * cfg.n_shared_experts * cfg.moe_d_ff * 3
    return f


def _layer_kinds(cfg: ModelConfig):
    from ..models.lm import layer_plan
    if cfg.family == "encdec":
        return [("encoder", cfg.n_enc_layers), ("decoder", cfg.n_layers)]
    out = []
    for kinds, count in layer_plan(cfg):
        for k in kinds:
            out.append((k, count))
    return out


def step_flops(cfg: ModelConfig, shape: ShapeSpec, *,
               kernelized: bool = False) -> float:
    """Whole-step FLOPs for the cell, as implemented."""
    B, S = shape.global_batch, shape.seq_len
    Vp, d = cfg.padded_vocab, cfg.d_model
    if shape.kind == "decode":
        f = 2.0 * B * d * Vp  # lm head (embed gather ~ 0 flops)
        for kind, count in _layer_kinds(cfg):
            if kind == "encoder":
                continue
            f += count * _layer_flops_decode(cfg, B, S, kind)
        return f
    S_text = S - cfg.frontend_seq if cfg.family == "vlm" else S
    f = 2.0 * B * S_text * d * Vp
    for kind, count in _layer_kinds(cfg):
        Sk = cfg.enc_seq if kind == "encoder" else S
        f += count * _layer_flops_full(cfg, B, Sk, kind, kernelized)
    if shape.kind == "train":
        f *= 4.0  # fwd + remat fwd + bwd(2x)
    return f


def step_bytes(cfg: ModelConfig, shape: ShapeSpec, *,
               moment_dtype: str = "float32") -> float:
    """Kernelized HBM byte model (whole step, all chips summed)."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    P = cfg.param_count()
    pbytes = P * DT
    mom = P * (1 if moment_dtype == "int8" else F32) * 2
    if shape.kind == "decode":
        # params once, caches read+slot write, small activations
        total = pbytes
        KV, hd = cfg.n_kv_heads, cfg.head_dim_
        for kind, count in _layer_kinds(cfg):
            if kind in ("dense", "moe", "hybrid", "decoder"):
                eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
                total += count * B * eff * 2 * KV * hd * DT   # cache read
            if kind == "decoder":
                total += count * B * cfg.enc_seq * 2 * KV * hd * DT
            if kind in ("ssm", "hybrid"):
                total += count * B * cfg.ssm_heads * cfg.ssm_headdim * \
                    cfg.ssm_state * F32 * 2                    # state r/w
        total += B * cfg.padded_vocab * DT                     # logits
        return total
    # train / prefill
    n_layers_total = sum(c for _, c in _layer_kinds(cfg))
    act = n_layers_total * B * S * d * DT                      # saved acts
    qkv_stream = 0.0
    for kind, count in _layer_kinds(cfg):
        Sk = cfg.enc_seq if kind == "encoder" else S
        width = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim_ \
            if kind != "ssm" else 2 * cfg.d_inner
        qkv_stream += count * B * Sk * width * DT * 2          # r + w
    logits = B * S * cfg.padded_vocab * F32
    if shape.kind == "prefill":
        return pbytes + act * 2 + qkv_stream + logits
    # train: params read 3x (fwd/remat/bwd) + grads w + update r/w + moments
    return pbytes * 3 + pbytes * 2 + mom * 2 + act * 4 + qkv_stream * 3 + \
        logits * 2
