"""Roofline-term derivation from compiled XLA artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum the *output* buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(documented convention; operand vs result differs by <2x for these ops and
is applied uniformly across baselines and optimized versions).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE op-name(` — TYPE may be a tuple containing /*index=N*/
# comments (hence `.*?` rather than `[^=]*?`); the op name at call position
# is never %-prefixed (operand references are).
_OP_RE = re.compile(
    r"=\s*(?P<ty>\(?[a-z0-9]+\[.*?)\s*"
    r"(?<!%)(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions: 0.4.x returns a
    list of per-computation dicts (take the entry-computation one, index 0),
    newer jax returns the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every array shape in a (possibly tuple) HLO type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes summed over the module (flat —
    correct only for fully-unrolled modules)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        out[op] += _shape_bytes(m.group("ty"))
    return out


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^\n]*\))?\s*"
                       r"(?:->[^\{]*)?\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\)\s*,.*?condition=\%?([\w\.\-]+)"
                       r",\s*body=\%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    cur_name = None
    cur_lines: List[str] = []
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line)
        if m and not line.lstrip().startswith(("ROOT", "%constant")):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1)
            cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
            if line.strip() == "}":
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_text: str) -> int:
    """Loop bound heuristic: the largest integer literal in the while
    condition (scan conditions compare the induction var to the length)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


_CALL_RE = re.compile(r"(?:call\(|fusion\().*?(?:to_apply|calls)=\%?"
                      r"([\w\.\-]+)")


def collective_bytes_while_aware(hlo_text: str,
                                 entry: Optional[str] = None
                                 ) -> Dict[str, int]:
    """Collective output bytes with while-loop bodies multiplied by their
    trip counts, and ``call``/fusion edges traversed with the caller's
    multiplier (at -O0 XLA does not inline calls, so e.g. shard_map bodies
    live in separate computations reached via call ops).
    """
    comps = _split_computations(hlo_text)
    entry_m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
    entry = entry or (entry_m.group(1) if entry_m else None)
    if entry is None or entry not in comps:
        return collective_bytes(hlo_text)

    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str, depth: int = 0) -> Dict[str, int]:
        """Bytes attributable to one execution of computation ``name``."""
        if name in memo:
            return memo[name]
        text = comps.get(name, "")
        out = {k: 0 for k in _COLLECTIVES}
        if depth > 16 or not text:
            return out
        memo[name] = out  # guard recursion
        for m in _OP_RE.finditer(text):
            out[m.group("op")] += _shape_bytes(m.group("ty"))
        for w in _WHILE_RE.finditer(text):
            cond, body = w.group(1), w.group(2)
            trips = _trip_count(comps.get(cond, ""))
            inner = total(body, depth + 1)
            for k in out:
                out[k] += trips * inner[k]
        for c in _CALL_RE.finditer(text):
            target = c.group(1)
            if target in comps and target != name:
                inner = total(target, depth + 1)
                for k in out:
                    out[k] += inner[k]
        memo[name] = out
        return out

    return total(entry)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-step FLOPs (all chips)
    hlo_bytes: float            # whole-step HBM bytes (all chips)
    coll_bytes: float           # per-chip collective bytes (see note)
    coll_breakdown: Dict[str, int]
    model_flops: float          # 6*N*D (or 6*N_active*D) convention

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Optimistic overlap model: step >= max(terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips * peak * step_time) under the overlap model."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape, kind: str) -> float:
    """6*N*D convention (N = active params for MoE).

    train: D = global tokens, x3 for fwd+bwd (6*N*D already includes bwd:
    2*N*D fwd + 4*N*D bwd = 6*N*D).  prefill: 2*N*D.  decode: 2*N*B.
    Attention window/quadratic terms are intentionally excluded (the
    convention) — the useful_flops_ratio column surfaces the gap.
    """
    n_active = cfg.active_param_count()
    if kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d_tokens
    if kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d_tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token each
