"""Production mesh construction.

``make_production_mesh`` is the fixed entry point the multi-pod dry-run
compiles against: 16x16 = 256 chips per pod (single-pod), 2x16x16 = 512
chips multi-pod.  Defined as a function so importing this module never
touches jax device state.

``make_tenant_mesh`` is the vNPU path: the hypervisor's topology mapper
picks the physical cores and the routing-table assignment becomes the
Mesh device layout (core/vmesh.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...], devices):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases — 0.4.x takes
    neither and defaults to the same Auto semantics."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes, devices=devices,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices)")
    return _make_mesh(shape, axes, devices[:n])


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over however many devices the test environment has."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return _make_mesh(shape, axes, devices[:n])
