"""Production mesh construction.

``make_production_mesh`` is the fixed entry point the multi-pod dry-run
compiles against: 16x16 = 256 chips per pod (single-pod), 2x16x16 = 512
chips multi-pod.  Defined as a function so importing this module never
touches jax device state.

``make_tenant_mesh`` is the vNPU path: the hypervisor's topology mapper
picks the physical cores and the routing-table assignment becomes the
Mesh device layout (core/vmesh.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over however many devices the test environment has."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
