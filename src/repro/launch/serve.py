"""Serving launcher: ``python -m repro.launch.serve --arch <id> --reduced``.

Spins up the batched prefill/decode engine on a (reduced) model and runs a
handful of synthetic requests — the CPU-runnable end-to-end serving driver
(deliverable (b)); on a pod the same engine runs on a vNPU tenant submesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..configs.base import reduce_for_smoke
    from ..models import build
    from ..serve import EngineConfig, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params,
                         EngineConfig(batch_size=args.requests, max_seq=128))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size - 1,
                                   size=args.prompt_len).astype(np.int32),
                      max_new_tokens=args.new_tokens)
    t0 = time.perf_counter()
    reqs = engine.run()
    dt = time.perf_counter() - t0
    for r in reqs:
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{engine.stats} in {dt:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
