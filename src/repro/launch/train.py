"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (small or full) training job on whatever devices exist —
the CPU container trains reduced configs end-to-end; on a pod the same
entry point shards over the production mesh.  Supports checkpoint/restart
(--resume), elastic recovery drills (--kill-device), and the vNPU tenant
path (--tenant rxc allocates the submesh through the hypervisor's
similar-topology mapper instead of taking the whole mesh).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced smoke config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--device-count", type=int, default=0,
                    help="force N host devices (set before jax init)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count}")

    import jax
    import jax.numpy as jnp

    from ..checkpoint import latest_step, restore_checkpoint
    from ..configs import get_config
    from ..configs.base import reduce_for_smoke
    from ..data import DataConfig, make_batch
    from ..models import build
    from ..train import AdamWConfig, TrainConfig, init_state, train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    bundle = build(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr, warmup_steps=5),
                       grad_accum=args.grad_accum)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, family=cfg.family,
                      frontend_seq=cfg.frontend_seq or cfg.enc_seq,
                      frontend_dim=cfg.frontend_dim)

    state = None
    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        params = bundle.init(jax.random.PRNGKey(0))
        like = init_state(params, tcfg.opt)
        state, start = restore_checkpoint(args.ckpt_dir, like)
        print(f"resumed from step {start}")

    def data_iter():
        step = start
        while True:
            yield {k: jnp.asarray(v) for k, v in make_batch(dcfg, step).items()}
            step += 1

    state, history = train_loop(
        bundle, tcfg, data_iter(), n_steps=args.steps, state=state,
        checkpoint_dir=args.ckpt_dir or None,
        checkpoint_every=args.ckpt_every)
    for h in history:
        print(json.dumps(h))
    print(f"final step={int(state['step'])} loss={history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
