import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the right step function is lowered against ShapeDtypeStruct
stand-ins (no allocation):

    train_4k     -> train_step (loss + grads + AdamW update)
    prefill_32k  -> prefill_step
    decode_32k   -> decode_step (one token, seq_len-deep cache)
    long_500k    -> decode_step (sub-quadratic archs only)

Cost-model subtlety: XLA's cost_analysis counts a while-loop (lax.scan)
body ONCE, so a scanned 48-layer model under-reports FLOPs ~48x.  We
therefore compile two extra *calibration* variants per cell with the layer
scan fully unrolled at small depths (L1, L2) and linear-fit
``cost(L) = a + b*L`` — exact, because every term of the step is affine in
layer count.  The full-depth scanned compile still provides
memory_analysis() (loop buffers are accounted) and proves the cell
compiles on the production mesh.

Run one cell:   python -m repro.launch.dryrun --arch qwen2_7b --shape train_4k
Run the matrix: python -m repro.launch.dryrun --all --jobs 4
(the orchestrator spawns one subprocess per cell for isolation).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "/root/repo/results/dryrun")


def cal_layers(cfg):
    """Calibration depths: smallest pair that contains >=1 of every
    repeating unit so the linear fit's slope is exact per family."""
    if cfg.family == "moe" and cfg.moe_interleave > 1:
        return (cfg.moe_interleave, 2 * cfg.moe_interleave)   # llama4: 2,4
    if cfg.family == "moe" and cfg.first_k_dense:
        return (cfg.first_k_dense + 1, cfg.first_k_dense + 2)  # deepseek: 2,3
    return (1, 2)


def _mesh_name(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def _reduced_layers(cfg, L: int):
    kw: Dict[str, Any] = {"n_layers": L}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = L
    return dataclasses.replace(cfg, **kw)


def _lower_step(cfg, shape, mesh, opt_cfg, recipe: str = "fsdp"):
    """Build + lower the step function for this cell.  Returns lowered.

    recipe: "fsdp" (paper-faithful baseline: params sharded over data+model)
            or "tp" (beyond-paper: TP/EP-only, params replicated over data —
            no per-layer all-gathers; only legal when params/16 fit HBM).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models import build
    from ..parallel import sharding as shd
    from ..train.loop import TrainConfig, make_train_step
    from ..train.state import init_state, state_logical_axes

    bundle = build(cfg)
    rules = shd.param_rules(mesh, fsdp=(recipe == "fsdp"))
    param_axes = bundle.param_logical_axes()
    pspecs = shd.param_specs(param_axes, rules)
    pshard = shd.named_shardings(mesh, pspecs)

    if shape.kind == "train":
        tcfg = TrainConfig(opt=opt_cfg)
        step_fn = make_train_step(bundle.loss, tcfg)
        state_shapes = jax.eval_shape(
            lambda: init_state(bundle.init(jax.random.PRNGKey(0)), opt_cfg))
        sspecs = shd.param_specs(state_logical_axes(param_axes, opt_cfg),
                                 rules)
        sshard = shd.named_shardings(mesh, sspecs)
        batch_sds = bundle.input_specs(shape)
        bshard = shd.named_shardings(mesh, shd.batch_specs(batch_sds, mesh))
        return jax.jit(step_fn, in_shardings=(sshard, bshard),
                       out_shardings=(sshard, None)
                       ).lower(state_shapes, batch_sds)
    if shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: bundle.init(jax.random.PRNGKey(0)))
        batch_sds = bundle.input_specs(shape)
        bshard = shd.named_shardings(mesh, shd.batch_specs(batch_sds, mesh))
        return jax.jit(bundle.prefill, in_shardings=(pshard, bshard)
                       ).lower(params_shapes, batch_sds)
    # decode
    params_shapes = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    specs = bundle.input_specs(shape)
    cshard = shd.named_shardings(mesh,
                                 shd.cache_specs(specs["caches"], mesh))
    tshard = shd.named_shardings(
        mesh, shd.batch_specs({"t": specs["token"]}, mesh))["t"]
    return jax.jit(bundle.decode,
                   in_shardings=(pshard, cshard, tshard,
                                 NamedSharding(mesh, P())),
                   out_shardings=(None, cshard),
                   ).lower(params_shapes, specs["caches"], specs["token"],
                           specs["pos"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_override: Optional[Dict[str, Any]] = None,
             skip_calibration: bool = True,
             recipe: str = "fsdp",
             attn_shard: Optional[str] = None) -> Dict[str, Any]:
    import jax

    from ..configs import SHAPES, applicable, get_config
    from ..models.common import (set_activation_rules, set_mesh_context,
                                 set_scan_unroll)
    from ..parallel import sharding as shd
    from ..roofline.analysis import (RooflineTerms, collective_bytes,
                                     collective_bytes_while_aware,
                                     cost_analysis_dict, model_flops_for)
    from ..roofline.analytic import step_bytes, step_flops
    from ..train.optimizer import AdamWConfig
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    if attn_shard:
        cfg = dataclasses.replace(cfg, attn_shard=attn_shard)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
            "kind": shape.kind}
    if not ok:
        cell.update(status="skip", reason=reason)
        return cell

    # roofline calibration is single-pod only (the multi-pod pass proves the
    # pod axis shards; §Roofline reads 16x16 cells)
    if multi_pod:
        skip_calibration = True

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    set_mesh_context(mesh, shd.batch_axes(mesh),
                     moe_ff_axis="data" if recipe == "tp" else None)
    set_activation_rules(shd.activation_rules(mesh))

    # int8 moments where fp32 optimizer state cannot fit 16 GB/chip
    opt_kw = {"moment_dtype": "int8"} if cfg.param_count() > 5e10 else {}
    if opt_override:
        opt_kw.update(opt_override)
    opt_cfg = AdamWConfig(**opt_kw)

    t0 = time.time()
    with mesh:
        # 1) full-depth scanned compile: proves the cell + memory analysis.
        #    opt-level 0: memory_analysis and SPMD partitioning (collectives)
        #    are unaffected, compile is ~15x faster on the 1-core container.
        set_scan_unroll(False)
        lowered = _lower_step(cfg, shape, mesh, opt_cfg, recipe=recipe)
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": "0"})
        mem = compiled.memory_analysis()
        full_cost = cost_analysis_dict(compiled)
        # collective accounting from the full module, while-loop aware
        coll_full = collective_bytes_while_aware(compiled.as_text())

        # 2) calibration compiles (unrolled small depths, default opt level
        #    so fusion-level bytes are honest) -> linear fit
        cal = []
        if not skip_calibration:
            set_scan_unroll(True)
            for L in cal_layers(cfg):
                lc = _lower_step(_reduced_layers(cfg, L), shape, mesh,
                                 opt_cfg, recipe=recipe)
                cc = lc.compile()
                cost = cost_analysis_dict(cc)
                coll = collective_bytes(cc.as_text())
                cal.append({"L": L,
                            "flops": float(cost.get("flops", 0.0)),
                            "bytes": float(cost.get("bytes accessed", 0.0)),
                            "coll": coll})
            set_scan_unroll(False)

    t_compile = time.time() - t0

    mem_d = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        mem_d[k] = getattr(mem, k, None)

    cell.update(status="ok", recipe=recipe,
                compile_seconds=t_compile, chips=chips,
                memory=mem_d,
                full_cost={"flops_per_device": float(full_cost.get("flops", 0)),
                           "bytes_per_device": float(
                               full_cost.get("bytes accessed", 0))},
                calibration=cal,
                opt=opt_kw or {"moment_dtype": "float32"})

    cell["coll_full"] = coll_full
    # roofline terms: analytic implementation-faithful FLOPs/bytes (see
    # roofline/analytic.py — validated within ~1% of unrolled XLA cost
    # analysis on dense cells), collectives parsed while-aware from the
    # compiled SPMD module.
    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=_mesh_name(multi_pod),
        chips=chips,
        hlo_flops=step_flops(cfg, shape),
        hlo_bytes=step_bytes(cfg, shape,
                             moment_dtype=opt_cfg.moment_dtype),
        coll_bytes=float(sum(coll_full.values())),
        coll_breakdown={k: int(v) for k, v in coll_full.items()},
        model_flops=model_flops_for(cfg, shape, shape.kind))
    cell["roofline"] = terms.as_dict()
    if cal:
        L1, L2 = (c["L"] for c in cal)
        Lfull = cfg.n_layers

        def fit(y1, y2):
            b = (y2 - y1) / (L2 - L1)
            a = y1 - b * L1
            return a + b * Lfull

        cell["xla_calibration"] = {
            "flops_total": fit(cal[0]["flops"], cal[1]["flops"]) * chips,
            "bytes_total": fit(cal[0]["bytes"], cal[1]["bytes"]) * chips,
        }
    return cell


def _cell_path(arch, shape, multi_pod, tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    return os.path.join(
        RESULTS_DIR, f"{arch}--{shape}--{_mesh_name(multi_pod)}{suffix}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--tag", default="", help="suffix results (perf variants)")
    ap.add_argument("--calibrate", action="store_true",
                    help="also run unrolled XLA cost calibration (slow)")
    ap.add_argument("--recipe", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--opt-int8", action="store_true")
    ap.add_argument("--attn-shard", default=None,
                    choices=[None, "auto", "heads", "seq", "replicated"])
    args = ap.parse_args()

    if args.all:
        from ..configs import ARCH_IDS, SHAPE_ORDER
        pods = [False, True] if args.multi_pod == "both" else \
            [args.multi_pod == "yes"]
        jobs = [(a, s, mp) for a in ARCH_IDS for s in SHAPE_ORDER
                for mp in pods]
        jobs = [(a, s, mp) for a, s, mp in jobs
                if not os.path.exists(_cell_path(a, s, mp, args.tag))]
        print(f"{len(jobs)} cells to run")
        procs: Dict[Any, Any] = {}
        failures = []
        while jobs or procs:
            while jobs and len(procs) < args.jobs:
                a, s, mp = jobs.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s,
                       "--multi-pod", "yes" if mp else "no"]
                if args.tag:
                    cmd += ["--tag", args.tag]
                if args.calibrate:
                    cmd += ["--calibrate"]
                print(f"[start] {a} {s} mp={mp}", flush=True)
                procs[subprocess.Popen(
                    cmd, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                    text=True)] = (a, s, mp, time.time())
            time.sleep(5)
            for pr in list(procs):
                if pr.poll() is None:
                    continue
                a, s, mp, t0 = procs.pop(pr)
                dt = time.time() - t0
                if pr.returncode != 0:
                    failures.append((a, s, mp))
                    out, err = pr.communicate()
                    print(f"[FAIL {dt:.0f}s] {a} {s} mp={mp}\n"
                          f"{err[-3000:]}", flush=True)
                else:
                    print(f"[ok {dt:.0f}s] {a} {s} mp={mp}", flush=True)
        print(f"done; failures={len(failures)}: {failures}")
        return 1 if failures else 0

    cell = run_cell(args.arch, args.shape, args.multi_pod == "yes",
                    skip_calibration=not args.calibrate,
                    recipe=args.recipe, attn_shard=args.attn_shard,
                    opt_override={"moment_dtype": "int8"}
                    if args.opt_int8 else None)
    path = _cell_path(args.arch, args.shape, args.multi_pod == "yes", args.tag)
    with open(path, "w") as f:
        json.dump(cell, f, indent=2)
    print(json.dumps({k: v for k, v in cell.items() if k != "memory"},
                     indent=2, default=str))
    if cell.get("status") == "ok":
        print("memory_analysis:", cell["memory"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
