"""Training state: a plain dict pytree (params + optimizer state + step) so
sharding trees, checkpoints and eval_shape all stay trivial."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, init_opt_state, opt_logical_axes

TrainState = Dict[str, Any]  # {"params", "opt", "step"}


def init_state(params, opt_cfg: AdamWConfig) -> TrainState:
    return {"params": params,
            "opt": init_opt_state(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def state_logical_axes(param_axes, opt_cfg: AdamWConfig):
    return {"params": param_axes,
            "opt": opt_logical_axes(param_axes, opt_cfg),
            "step": ()}
