"""AdamW from scratch (no optax), with optional int8 block-quantized moments.

The quantized variant (``moment_dtype="int8"``) stores both Adam moments as
int8 with per-block (128) absmax scales — 4x smaller optimizer state.  This
is what lets llama4-maverick-400B training state fit a 16 GB/chip v5e pod
(see DESIGN.md §Parallelism and EXPERIMENTS.md §Dry-run memory table); it is
also a distributed-optimization trick in its own right (less state to
checkpoint / re-shard on elastic events).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # float32 | int8
    warmup_steps: int = 100


# ---------------------------------------------------------------------------
# int8 block quantization
# ---------------------------------------------------------------------------

def _pad_to(x: jnp.ndarray, m: int) -> Tuple[jnp.ndarray, int]:
    n = x.shape[-1]
    pad = (-n) % m
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def quantize_q8(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    xp, _ = _pad_to(x, QBLOCK)
    blocks = xp.reshape(*xp.shape[:-1], xp.shape[-1] // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_q8(qt: Dict[str, jnp.ndarray], orig_len: int) -> jnp.ndarray:
    x = (qt["q"].astype(jnp.float32) * qt["scale"])
    x = x.reshape(*x.shape[:-2], x.shape[-2] * QBLOCK)
    return x[..., :orig_len]


def _zeros_moment(p: jnp.ndarray, dtype: str):
    if dtype == "int8":
        n = p.shape[-1] if p.ndim else 1
        pn = n + ((-n) % QBLOCK)
        shape = p.shape[:-1] + (pn // QBLOCK, QBLOCK) if p.ndim else (1, QBLOCK)
        return {"q": jnp.zeros(shape, jnp.int8),
                "scale": jnp.zeros(shape[:-1] + (1,), jnp.float32)}
    return jnp.zeros_like(p, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def init_opt_state(params, cfg: AdamWConfig):
    m = jax.tree.map(lambda p: _zeros_moment(p, cfg.moment_dtype), params)
    v = jax.tree.map(lambda p: _zeros_moment(p, cfg.moment_dtype), params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = _schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    q8 = cfg.moment_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        n = p.shape[-1] if p.ndim else 1
        mf = dequantize_q8(m, n) if q8 else m
        vf = dequantize_q8(v, n) if q8 else v
        if p.ndim == 0:
            mf = mf.reshape(()) if q8 else mf
            vf = vf.reshape(()) if q8 else vf
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mh = mf / b1c
        vh = vf / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        nm = quantize_q8(mf if p.ndim else mf.reshape(1)) if q8 else mf
        nv = quantize_q8(vf if p.ndim else vf.reshape(1)) if q8 else vf
        return new_p.astype(p.dtype), nm, nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    is_q = lambda t: isinstance(t, dict) and set(t) == {"q", "scale"}
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    mdef = jax.tree.structure(opt_state["m"], is_leaf=is_q)
    new_m = jax.tree.unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(mdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}


def opt_logical_axes(param_axes, cfg: AdamWConfig):
    """Sharding metadata for the optimizer state (mirrors the params)."""
    if cfg.moment_dtype == "int8":
        def mom_axes(t):
            # (..., blocks, QBLOCK): keep the leading axes' rules; the blocks
            # dim is NOT sharded (block counts rarely divide the mesh axis)
            t = tuple(t)
            return {"q": t[:-1] + (None, None), "scale": t[:-1] + (None, None)}
        m = jax.tree.map(mom_axes, param_axes,
                         is_leaf=lambda t: isinstance(t, tuple))
    else:
        m = param_axes
    return {"m": m, "v": m, "count": ()}
