"""Training loop: jit-compiled step (grad -> clip -> AdamW), gradient
accumulation, optional int8 gradient compression with error feedback,
straggler detection hooks, checkpoint/restart and elastic-remap recovery.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update
from .state import TrainState, init_state


@dataclasses.dataclass
class TrainConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    grad_accum: int = 1
    compress_grads: bool = False      # int8 all-reduce w/ error feedback
    straggler_threshold: float = 3.0  # x median step time triggers the hook


def make_train_step(loss_fn: Callable, tcfg: TrainConfig,
                    compress_fn: Optional[Callable] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    With grad_accum > 1 the batch's leading dim is split into microbatches
    and gradients are averaged in a scan (compute/comm overlap: XLA overlaps
    each microbatch's reduce with the next microbatch's compute).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params = state["params"]
        if tcfg.grad_accum > 1:
            def micro(i, b):
                return jax.tree.map(
                    lambda x: x.reshape(tcfg.grad_accum,
                                        x.shape[0] // tcfg.grad_accum,
                                        *x.shape[1:])[i] if x.ndim else x, b)

            def acc_step(carry, i):
                g_acc, l_acc = carry
                loss, _, g = grads_of(params, micro(i, batch))
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)),
                jnp.arange(tcfg.grad_accum))
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss = loss_sum / tcfg.grad_accum
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compress_fn is not None:
            grads = compress_fn(grads)

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# host-side driver with fault-tolerance hooks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepTimer:
    """Straggler detection: per-step wall times; flags steps that exceed
    ``threshold`` x the running median (on a real pod this feeds the
    hypervisor's remap/elastic-DP decision)."""
    threshold: float = 3.0
    times: List[float] = dataclasses.field(default_factory=list)
    stragglers: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        med = sorted(self.times)[len(self.times) // 2]
        slow = len(self.times) >= 5 and dt > self.threshold * med
        if slow:
            self.stragglers.append(step)
        return slow


def train_loop(bundle, tcfg: TrainConfig, data_iter: Iterable, *,
               n_steps: int, state: Optional[TrainState] = None,
               key=None, checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0,
               on_straggler: Optional[Callable[[int], None]] = None,
               log_every: int = 10) -> Tuple[TrainState, List[Dict]]:
    """Single-process training driver used by examples/train_100m.py and the
    integration tests.  Checkpointing via repro.checkpoint (restart-safe)."""
    from ..checkpoint.ckpt import save_checkpoint

    if state is None:
        params = bundle.init(key if key is not None else
                             jax.random.PRNGKey(0))
        state = init_state(params, tcfg.opt)
    step_fn = jax.jit(make_train_step(bundle.loss, tcfg))
    timer = StepTimer(tcfg.straggler_threshold)
    history: List[Dict] = []
    start = int(state["step"])
    for i, batch in enumerate(data_iter):
        if i >= n_steps:
            break
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if timer.record(start + i, dt) and on_straggler:
            on_straggler(start + i)
        if (i % log_every) == 0 or i == n_steps - 1:
            history.append({k: float(v) for k, v in metrics.items()
                            if jnp.ndim(v) == 0})
        if checkpoint_dir and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, state, step=start + i + 1)
    return state, history
