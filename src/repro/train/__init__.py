from .optimizer import AdamWConfig, init_opt_state, adamw_update
from .state import init_state, state_logical_axes
from .loop import TrainConfig, make_train_step, train_loop, StepTimer
