"""Mamba-2 SSD (state-space duality) layer — chunked scan, pure JAX.

Faithful to the SSD block of arXiv:2405.21060 with one shard-friendly
restructuring: the fused ``in_proj`` is split into separate projections
(z, x, B, C, dt) so the head-parallel parts (z, x, dt) can be tensor-sharded
over the ``model`` axis while the group-shared B/C stay replicated
(n_groups=1 in the assigned configs).  The short causal conv is likewise
split into an x-conv (sharded channels) and a BC-conv (replicated).

The chunked algorithm runs as a `lax.scan` over sequence chunks so the
intra-chunk (q x q) decay matrices never materialize for the whole sequence
— per-step memory is O(chunk^2), total work O(S*chunk + S*N*P), the same
blocking a TPU kernel wants (see kernels/ssd_scan.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Params, dense_init, get_scan_unroll, rmsnorm


def ssd_init(cfg, key, dtype) -> Tuple[Params, Dict]:
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = 1  # n_groups
    ks = jax.random.split(key, 8)
    p = {
        "w_z": dense_init(ks[0], (d, di), dtype),
        "w_x": dense_init(ks[1], (d, di), dtype),
        "w_B": dense_init(ks[2], (d, G * N), dtype),
        "w_C": dense_init(ks[3], (d, G * N), dtype),
        "w_dt": dense_init(ks[4], (d, H), dtype),
        "conv_x": (jax.random.normal(ks[5], (cfg.ssm_conv_width, di),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_BC": (jax.random.normal(ks[6], (cfg.ssm_conv_width, 2 * G * N),
                                      jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[7], (di, d), dtype, in_axis=0),
    }
    ax = {
        "w_z": ("embed", "heads"), "w_x": ("embed", "heads"),
        "w_B": ("embed", None), "w_C": ("embed", None),
        "w_dt": ("embed", "heads"),
        "conv_x": (None, "heads"), "conv_BC": (None, None),
        "A_log": ("heads",), "D": ("heads",), "dt_bias": ("heads",),
        "norm": ("heads",),
        "w_out": ("heads", "embed"),
    }
    return p, ax


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _segsum(dA: jnp.ndarray) -> jnp.ndarray:
    """dA: (B,q,H) -> (B,H,q,q) with out[...,i,j] = sum_{j<k<=i} dA_k
    (lower-triangular), -inf above the diagonal."""
    q = dA.shape[1]
    x = jnp.swapaxes(dA, 1, 2)                       # (B,H,q)
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # i,j -> cs_i - cs_j
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(x, dt, A, B, C, chunk: int,
                 init_state: Optional[jnp.ndarray] = None,
                 return_state: bool = False):
    """Chunked SSD: x (B,S,H,P), dt (B,S,H), A (H), B/C (B,S,G,N).

    Returns y (B,S,H,P) [and final state (B,H,P,N)].
    This is also the oracle for kernels/ssd_scan.py.
    """
    Bsz, S, H, P = x.shape
    G = B.shape[2]
    N = B.shape[3]
    hpg = H // G
    q = chunk
    while S % q:
        q -= 1
    nc = S // q

    xf = (x * dt[..., None]).astype(jnp.float32)     # fold dt into x
    xc = xf.reshape(Bsz, nc, q, H, P)
    dtc = dt.reshape(Bsz, nc, q, H)
    Bc = B.astype(jnp.float32).reshape(Bsz, nc, q, G, N)
    Cc = C.astype(jnp.float32).reshape(Bsz, nc, q, G, N)

    state0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, inp):
        xb, dtb, Bb, Cb = inp                        # (B,q,H,P) etc.
        dA = dtb * A                                  # (B,q,H)
        cum = jnp.cumsum(dA, axis=1)                  # (B,q,H)
        L = jnp.exp(_segsum(dA))                      # (B,H,q,q)
        Lg = L.reshape(Bsz, G, hpg, q, q)
        xg = xb.reshape(Bsz, q, G, hpg, P)
        # intra-chunk
        scores = jnp.einsum("bqgn,bsgn->bgqs", Cb, Bb)          # (B,G,q,q)
        y_diag = jnp.einsum("bgqs,bghqs,bsghp->bqghp", scores, Lg, xg)
        # inter-chunk: contribution of the incoming state
        dec = jnp.exp(cum).reshape(Bsz, q, G, hpg)               # (B,q,G,hpg)
        stg = state.reshape(Bsz, G, hpg, P, N)
        y_off = jnp.einsum("bqgn,bghpn,bqgh->bqghp", Cb, stg, dec)
        y = (y_diag + y_off).reshape(Bsz, q, H, P)
        # new chunk state
        dec_st = jnp.exp(cum[:, -1:, :] - cum)                   # (B,q,H)
        contrib = jnp.einsum("bsgn,bsghp->bghpn",
                             Bb, (xb * dec_st[..., None]).reshape(
                                 Bsz, q, G, hpg, P))
        chunk_decay = jnp.exp(cum[:, -1, :])                     # (B,H)
        state_new = state * chunk_decay[..., None, None] + \
            contrib.reshape(Bsz, H, P, N)
        return state_new, y

    inputs = (jnp.swapaxes(xc, 0, 1), jnp.swapaxes(dtc, 0, 1),
              jnp.swapaxes(Bc, 0, 1), jnp.swapaxes(Cc, 0, 1))
    state, ys = jax.lax.scan(jax.checkpoint(step), state0, inputs,
                             unroll=True if get_scan_unroll() else 1)
    y = jnp.swapaxes(ys, 0, 1).reshape(Bsz, S, H, P)
    if return_state:
        return y.astype(x.dtype), state
    return y.astype(x.dtype)


def ssd_forward(cfg, p: Params, x: jnp.ndarray, *,
                init_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Full SSD block: project -> conv -> SSD scan -> gate -> out-proj.

    x: (B,S,d) -> (B,S,d).  With ``return_state`` also returns the decode
    cache dict (final SSM state + conv tails) so prefill can seed decoding.
    """
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    K = cfg.ssm_conv_width
    z = jnp.einsum("bsd,dh->bsh", x, p["w_z"])
    xin_pre = jnp.einsum("bsd,dh->bsh", x, p["w_x"])
    BC_pre = jnp.einsum("bsd,dh->bsh", x,
                        jnp.concatenate([p["w_B"], p["w_C"]], axis=1))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])

    xin = _causal_conv(xin_pre, p["conv_x"])
    BC = _causal_conv(BC_pre, p["conv_BC"])
    Bm, Cm = jnp.split(BC, 2, axis=-1)

    Bsz, S = x.shape[0], x.shape[1]
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xin.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, 1, N)
    Cm = Cm.reshape(Bsz, S, 1, N)

    out = ssd_scan_ref(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                       init_state=init_state, return_state=return_state)
    y, state = out if return_state else (out, None)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, H * P).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.rms_eps)
    y = jnp.einsum("bsh,hd->bsd", y, p["w_out"])
    if return_state:
        cache = {"state": state,
                 "conv_x": xin_pre[:, S - (K - 1):, :],
                 "conv_BC": BC_pre[:, S - (K - 1):, :]}
        return y, cache
    return y


# ---------------------------------------------------------------------------
# decode: recurrent single-token step
# ---------------------------------------------------------------------------

def init_ssd_cache(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    K = cfg.ssm_conv_width
    di = cfg.d_inner
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, di), dtype),
        "conv_BC": jnp.zeros((batch, K - 1, 2 * N), dtype),
    }


def _conv_step(buf: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray):
    """buf (B,K-1,C) holds previous inputs; xt (B,C).  Returns (y, new_buf)."""
    full = jnp.concatenate([buf, xt[:, None, :]], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32))
    return jax.nn.silu(y).astype(xt.dtype), full[:, 1:, :]


def ssd_decode_step(cfg, p: Params, x: jnp.ndarray, cache: Dict):
    """x: (B,1,d) -> (y (B,1,d), new_cache)."""
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = x[:, 0, :]
    z = xt @ p["w_z"]
    xin = xt @ p["w_x"]
    BC = xt @ jnp.concatenate([p["w_B"], p["w_C"]], axis=1)
    dt = xt @ p["w_dt"]

    xin, conv_x = _conv_step(cache["conv_x"], xin, p["conv_x"])
    BC, conv_BC = _conv_step(cache["conv_BC"], BC, p["conv_BC"])
    Bm, Cm = jnp.split(BC, 2, axis=-1)                       # (B,N) each

    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(dt * A)                                      # (B,H)
    xh = xin.reshape(-1, H, P).astype(jnp.float32)
    state = cache["state"] * dA[..., None, None] + \
        jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(jnp.float32), xh, dt)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(-1, H * P).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.rms_eps)
    y = (y @ p["w_out"])[:, None, :]
    return y, {"state": state, "conv_x": conv_x, "conv_BC": conv_BC}
