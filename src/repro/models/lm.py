"""Unified language model covering all assigned decoder-only families
(dense / moe / ssm / hybrid / vlm); the whisper encoder-decoder lives in
``whisper.py`` and reuses the same blocks.

Layer stacks are scanned (`lax.scan` over stacked params) with per-layer
remat — HLO stays compact for 48-layer models and activation memory is
bounded by one layer.  MoE interleaving (llama4) scans over (dense, moe)
*pairs* so the stack stays homogeneous.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import block_forward, block_init, init_block_cache
from .common import (Params, apply_norm, dtype_of, embed_init,
                     get_scan_unroll, norm_init, softmax_cross_entropy,
                     with_logical_constraint)


def layer_plan(cfg) -> List[Tuple[Tuple[str, ...], int]]:
    """[(kinds-per-scan-step, count), ...] — homogeneous scan stacks."""
    if cfg.family in ("dense", "vlm"):
        return [(("dense",), cfg.n_layers)]
    if cfg.family == "ssm":
        return [(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        return [(("hybrid",), cfg.n_layers)]
    if cfg.family == "moe":
        plan: List[Tuple[Tuple[str, ...], int]] = []
        if cfg.moe_interleave > 1:
            pairs = cfg.n_layers // cfg.moe_interleave
            kinds = tuple(["dense"] * (cfg.moe_interleave - 1) + ["moe"])
            return [(kinds, pairs)]
        if cfg.first_k_dense:
            plan.append((("dense",), cfg.first_k_dense))
        plan.append((("moe",), cfg.n_layers - cfg.first_k_dense))
        return plan
    raise ValueError(f"layer_plan: unhandled family {cfg.family}")


def _stack_init(cfg, key, dtype, kinds: Tuple[str, ...], count: int):
    """vmap the per-layer init over the stack dim."""
    def one(k):
        ks = jax.random.split(k, len(kinds))
        p = {}
        for i, kind in enumerate(kinds):
            bp, _ = block_init(cfg, ks[i], dtype, kind)
            p[f"b{i}"] = bp
        return p
    keys = jax.random.split(key, count)
    params = jax.vmap(one)(keys)
    # logical axes: same per layer, with a leading "layers" axis
    _, ax0 = block_init(cfg, jax.random.PRNGKey(0), dtype, kinds[0])
    ax = {}
    for i, kind in enumerate(kinds):
        _, bx = block_init(cfg, jax.random.PRNGKey(0), dtype, kind)
        ax[f"b{i}"] = jax.tree.map(lambda t: ("layers",) + tuple(t), bx,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return params, ax


def init_params(cfg, key) -> Tuple[Params, Dict]:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4 + len(layer_plan(cfg)))
    p: Params = {"embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                     dtype)}
    ax: Dict = {"embed": ("vocab", "embed")}
    stacks = []
    stack_axes = []
    for i, (kinds, count) in enumerate(layer_plan(cfg)):
        sp, sax = _stack_init(cfg, ks[2 + i], dtype, kinds, count)
        stacks.append(sp)
        stack_axes.append(sax)
    p["stacks"] = stacks
    ax["stacks"] = stack_axes
    p["final_norm"], ax["final_norm"] = norm_init(cfg, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model,
                                  dtype).T
        ax["lm_head"] = ("embed", "vocab")
    return p, ax


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(cfg, p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["embed"], tokens, axis=0)
    return with_logical_constraint(x, "batch", None, None)


def unembed(cfg, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return with_logical_constraint(logits, "batch", None, "vocab_act")


def build_inputs(cfg, p: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Token embeddings, with the modality-frontend stub prepended (vlm)."""
    x = embed_tokens(cfg, p, batch["tokens"])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _scan_stack(cfg, stack_params, x, kinds: Tuple[str, ...], *,
                caches=None, cache_pos=None, collect_cache: bool = False,
                enc_out=None):
    """Scan one homogeneous stack.  Returns (x, new_caches_or_None, aux)."""
    init = (x, jnp.zeros((), jnp.float32))

    def apply_layer(h, aux, sp, layer_cache):
        new_caches = {}
        for i, kind in enumerate(kinds):
            lc = layer_cache[f"b{i}"] if layer_cache is not None else None
            h, nc, a = block_forward(cfg, sp[f"b{i}"], h, kind,
                                     cache=lc, cache_pos=cache_pos,
                                     enc_out=enc_out)
            aux = aux + a
            new_caches[f"b{i}"] = nc
        return h, aux, new_caches

    unroll = get_scan_unroll()
    if caches is None:
        def body(carry, sp):
            h, aux, ncs = apply_layer(carry[0], carry[1], sp, None)
            return (h, aux), (ncs if collect_cache else None)
        (x, aux), ys = jax.lax.scan(jax.checkpoint(body), init, stack_params,
                                    unroll=True if unroll else 1)
    else:
        def body(carry, xs):
            sp, lc = xs
            h, aux, ncs = apply_layer(carry[0], carry[1], sp, lc)
            return (h, aux), ncs
        (x, aux), ys = jax.lax.scan(jax.checkpoint(body), init,
                                    (stack_params, caches),
                                    unroll=True if unroll else 1)
    return x, ys, aux


def forward(cfg, p: Params, batch: Dict[str, jnp.ndarray], *,
            collect_cache: bool = False):
    """Full-sequence forward.  Returns (logits, caches, aux_loss)."""
    x = build_inputs(cfg, p, batch)
    all_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for stack_params, (kinds, _) in zip(p["stacks"], layer_plan(cfg)):
        x, ys, aux = _scan_stack(cfg, stack_params, x, kinds,
                                 collect_cache=collect_cache)
        aux_total = aux_total + aux
        all_caches.append(ys)
    x = apply_norm(cfg, x, p["final_norm"])
    logits = unembed(cfg, p, x)
    return logits, (all_caches if collect_cache else None), aux_total


def loss_fn(cfg, p: Params, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE (shift-by-one), masking frontend positions for VLMs."""
    logits, _, aux = forward(cfg, p, batch)
    tokens = batch["tokens"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        n_patch = batch["patch_embeds"].shape[1]
        logits = logits[:, n_patch:, :]
    ce = softmax_cross_entropy(logits[:, :-1, :], tokens[:, 1:],
                               cfg.vocab_size)
    loss = jnp.mean(ce)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "ce": loss}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int) -> List[Any]:
    """Decode cache: one stacked pytree per stack (leading dim = #layers)."""
    dtype = dtype_of(cfg.param_dtype)
    caches = []
    for kinds, count in layer_plan(cfg):
        def one(_):
            return {f"b{i}": init_block_cache(cfg, kind, batch, max_seq, dtype)
                    for i, kind in enumerate(kinds)}
        caches.append(jax.vmap(one)(jnp.arange(count)))
    return caches


def decode_step(cfg, p: Params, caches: List[Any], token: jnp.ndarray,
                pos: jnp.ndarray):
    """One token for the whole batch: token (B,1) int32, pos () int32.

    Returns (logits (B,1,V), new_caches).
    """
    x = embed_tokens(cfg, p, token)
    new_caches = []
    for stack_params, cache, (kinds, _) in zip(p["stacks"], caches,
                                               layer_plan(cfg)):
        x, ys, _ = _scan_stack(cfg, stack_params, x, kinds,
                               caches=cache, cache_pos=pos)
        new_caches.append(ys)
    x = apply_norm(cfg, x, p["final_norm"])
    logits = unembed(cfg, p, x)
    return logits, new_caches


def prefill(cfg, p: Params, batch: Dict[str, jnp.ndarray]):
    """Process the prompt; returns (last_logits, caches-with-kv)."""
    logits, caches, _ = forward(cfg, p, batch, collect_cache=True)
    return logits[:, -1:, :], caches
