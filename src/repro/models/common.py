"""Shared model building blocks (pure JAX, no flax).

Parameters are plain dict pytrees.  Every initializer returns
``(params, logical_axes)`` where ``logical_axes`` mirrors the param tree with
tuples of *logical axis names* per dimension; ``repro.parallel.sharding``
maps those to mesh PartitionSpecs.  This is the MaxText-style logical-axis
indirection that lets one model definition serve every mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape: Sequence[int], dtype, in_axis: int = -2) -> jnp.ndarray:
    """Truncated-normal fan-in init (what llama-family checkpoints resemble)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x: jnp.ndarray, p: Params) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.rms_eps)
    return rmsnorm(x, p["scale"], cfg.rms_eps)


def norm_init(cfg, d: int, dtype) -> Tuple[Params, Axes]:
    if cfg.norm == "layernorm":
        return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
                {"scale": ("embed",), "bias": ("embed",)})
    return ({"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)})


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# mesh context (logical names resolved lazily)
# ---------------------------------------------------------------------------

_ACTIVATION_RULES: Dict[str, Optional[Any]] = {}
_MESH_CTX: Dict[str, Any] = {"mesh": None, "data_spec": ("data",),
                             "model_axis": "model", "moe_ff_axis": None}


def set_activation_rules(rules: Dict[str, Optional[Any]]) -> None:
    """Install logical->mesh rules for activation constraints (see
    parallel/sharding.py).  No-op outside a mesh context."""
    global _ACTIVATION_RULES
    _ACTIVATION_RULES = dict(rules)


def set_mesh_context(mesh, data_spec=("data",), model_axis="model",
                     moe_ff_axis=None) -> None:
    """Install the mesh used by shard_map-based modules (attention, MoE).
    ``data_spec`` is the tuple of mesh axes that shard the batch dim
    (("pod","data") on the multi-pod mesh).  ``moe_ff_axis`` shards the
    expert hidden dim (TP/EP recipe: expert weights 2D-sharded, no
    gathers)."""
    _MESH_CTX["mesh"] = mesh
    _MESH_CTX["data_spec"] = tuple(data_spec)
    _MESH_CTX["model_axis"] = model_axis
    _MESH_CTX["moe_ff_axis"] = moe_ff_axis


def get_mesh_context():
    return (_MESH_CTX["mesh"], _MESH_CTX["data_spec"], _MESH_CTX["model_axis"])


def get_moe_ff_axis():
    return _MESH_CTX["moe_ff_axis"]


def clear_mesh_context() -> None:
    _MESH_CTX["mesh"] = None
    set_activation_rules({})


_SCAN_UNROLL = {"on": False}


def set_scan_unroll(on: bool) -> None:
    """Dry-run roofline mode: fully unroll layer scans so XLA cost analysis
    sees every layer (while-loop bodies are otherwise counted once).  Used
    only for the small-L calibration lowers in launch/dryrun.py."""
    _SCAN_UNROLL["on"] = bool(on)


def get_scan_unroll() -> bool:
    return _SCAN_UNROLL["on"]


def with_logical_constraint(x: jnp.ndarray, *logical_axes: Optional[str]):
    """Apply with_sharding_constraint if rules are installed; identity
    otherwise (lets the same model run un-meshed in unit tests)."""
    if not _ACTIVATION_RULES:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(*[_ACTIVATION_RULES.get(a) if a else None for a in logical_axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in scope


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          vocab_size: int) -> jnp.ndarray:
    """Token-level CE with padded-vocab masking (iota mask — no copies, stays
    shardable when the vocab dim is model-sharded)."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab_size:
        iota = jax.lax.broadcasted_iota(jnp.int32, (vpad,), 0)
        logits = jnp.where(iota < vocab_size, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, vpad, dtype=logits.dtype)
    picked = jnp.sum(logits * onehot, axis=-1)
    return lse - picked
