from .api import ModelBundle, build
