"""Transformer/SSM/hybrid/MoE blocks, composed from attention/ssd/moe.

Block kinds (selected by the LM from the config family):

  dense   : norm -> attn -> +res ; norm -> mlp  -> +res
  moe     : norm -> attn -> +res ; norm -> moe  -> +res   (+ shared experts)
  ssm     : norm -> ssd  -> +res                           (mamba2: no FFN)
  hybrid  : norm -> (attn || ssd) -> +res ; norm -> mlp -> +res   (hymba)
  encoder : norm -> bidir attn -> +res ; norm -> mlp -> +res      (whisper)
  decoder : norm -> causal attn -> +res ; norm -> cross-attn -> +res ;
            norm -> mlp -> +res                                   (whisper)

Every init returns (params, logical_axes).  Every forward threads an optional
per-layer cache (decode) and an aux-loss accumulator (MoE load balance).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_forward, attention_init, init_kv_cache
from .common import Params, apply_norm, dense_init, norm_init
from .moe import moe_forward, moe_init
from .ssd import init_ssd_cache, ssd_decode_step, ssd_forward, ssd_init
from .common import get_mesh_context


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg, key, dtype) -> Tuple[Params, Dict]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        p = {"wg": dense_init(ks[0], (d, f), dtype),
             "wu": dense_init(ks[1], (d, f), dtype),
             "wd": dense_init(ks[2], (f, d), dtype, in_axis=0)}
        ax = {"wg": ("embed", "ff"), "wu": ("embed", "ff"),
              "wd": ("ff", "embed")}
    else:  # gelu (whisper)
        p = {"w1": dense_init(ks[0], (d, f), dtype),
             "b1": jnp.zeros((f,), dtype),
             "w2": dense_init(ks[1], (f, d), dtype, in_axis=0),
             "b2": jnp.zeros((d,), dtype)}
        ax = {"w1": ("embed", "ff"), "b1": ("ff",),
              "w2": ("ff", "embed"), "b2": ("embed",)}
    return p, ax


def mlp_forward(cfg, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["wu"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("bsf,fd->bsd", h, p["wd"])
    h = jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def block_init(cfg, key, dtype, kind: str) -> Tuple[Params, Dict]:
    ks = jax.random.split(key, 8)
    p: Params = {}
    ax: Dict = {}
    if kind in ("dense", "moe", "hybrid", "encoder", "decoder"):
        p["ln1"], ax["ln1"] = norm_init(cfg, cfg.d_model, dtype)
        p["attn"], ax["attn"] = attention_init(cfg, ks[0], dtype)
    if kind == "hybrid":
        p["ssd"], ax["ssd"] = ssd_init(cfg, ks[1], dtype)
    if kind == "ssm":
        p["ln1"], ax["ln1"] = norm_init(cfg, cfg.d_model, dtype)
        p["ssd"], ax["ssd"] = ssd_init(cfg, ks[1], dtype)
    if kind == "decoder":
        p["ln_cross"], ax["ln_cross"] = norm_init(cfg, cfg.d_model, dtype)
        p["cross"], ax["cross"] = attention_init(cfg, ks[2], dtype, cross=True)
    if kind in ("dense", "hybrid", "encoder", "decoder"):
        p["ln2"], ax["ln2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"], ax["mlp"] = mlp_init(cfg, ks[3], dtype)
    if kind == "moe":
        p["ln2"], ax["ln2"] = norm_init(cfg, cfg.d_model, dtype)
        p["moe"], ax["moe"] = moe_init(cfg, ks[3], dtype)
    return p, ax


def block_forward(cfg, p: Params, x: jnp.ndarray, kind: str, *,
                  cache: Optional[Dict] = None,
                  cache_pos: Optional[jnp.ndarray] = None,
                  enc_out: Optional[jnp.ndarray] = None,
                  window_override: Optional[int] = None
                  ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (y, new_cache, aux_loss).  ``cache`` is this layer's slice.

    In full (train/prefill) mode the returned 'cache' holds the K/V computed
    for the sequence (prefill seeds the decode cache from it); SSM blocks
    return their final state + conv tails.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    use_rope = cfg.norm != "layernorm"  # whisper uses learned pos embeds
    window = cfg.sliding_window if window_override is None else window_override
    decoding = cache is not None and x.shape[1] == 1

    if kind == "ssm":
        h = apply_norm(cfg, x, p["ln1"])
        if decoding:
            y, new_cache = ssd_decode_step(cfg, p["ssd"], h, cache)
        else:
            y, new_cache = ssd_forward(cfg, p["ssd"], h, return_state=True)
        return x + y, new_cache, aux

    # --- attention sub-block ---
    h = apply_norm(cfg, x, p["ln1"])
    causal = kind != "encoder"
    if decoding:
        attn_cache = {"k": cache["k"], "v": cache["v"]}
        y_attn, kv = attention_forward(
            cfg, p["attn"], h, causal=causal, window=window,
            use_rope=use_rope, cache=attn_cache, cache_pos=cache_pos)
        new_cache.update(kv)
    else:
        y_attn, kv = attention_forward(
            cfg, p["attn"], h, causal=causal, window=window,
            use_rope=use_rope)
        if kv is not None:
            new_cache.update({"k": kv[0], "v": kv[1]})

    if kind == "hybrid":
        if decoding:
            ssd_cache = {k: cache[k] for k in ("state", "conv_x", "conv_BC")}
            y_ssd, ssd_new = ssd_decode_step(cfg, p["ssd"], h, ssd_cache)
            new_cache.update(ssd_new)
        else:
            y_ssd, ssd_new = ssd_forward(cfg, p["ssd"], h, return_state=True)
            new_cache.update(ssd_new)
        # hymba: fuse the parallel attention and SSM head outputs
        y_attn = 0.5 * (y_attn + y_ssd)
    x = x + y_attn

    if kind == "decoder":
        h = apply_norm(cfg, x, p["ln_cross"])
        if decoding:
            y_cross, _ = attention_forward(
                cfg, p["cross"], h,
                precomputed_kv=(cache["cross_k"], cache["cross_v"]))
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            y_cross, ckv = attention_forward(cfg, p["cross"], h,
                                             kv_x=enc_out, causal=False,
                                             use_rope=False)
            if ckv is not None:
                new_cache["cross_k"], new_cache["cross_v"] = ckv
        x = x + y_cross

    # --- FFN sub-block ---
    h = apply_norm(cfg, x, p["ln2"])
    if kind == "moe":
        mesh, data_spec, model_axis = get_mesh_context()
        y, aux = moe_forward(cfg, p["moe"], h, mesh=mesh,
                             data_spec=data_spec, model_axis=model_axis)
    else:
        y = mlp_forward(cfg, p["mlp"], h)
    return x + y, new_cache, aux


def init_block_cache(cfg, kind: str, batch: int, max_seq: int, dtype) -> Dict:
    """Decode-cache structure for one layer of the given kind."""
    c: Dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid", "decoder", "encoder"):
        c.update(init_kv_cache(cfg, batch, max_seq, dtype))
    if kind in ("ssm", "hybrid"):
        c.update(init_ssd_cache(cfg, batch, dtype))
    return c
