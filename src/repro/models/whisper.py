"""Whisper-large-v3 backbone: encoder-decoder on the shared blocks.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
delivers precomputed frame embeddings (B, enc_seq, d_model).  Learned
positional embeddings (sized to the assigned shapes — the real model stops
at 448 decoder positions; deviation noted in DESIGN.md), LayerNorm, GELU.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import block_forward, block_init, init_block_cache
from .common import (Params, apply_norm, dtype_of, embed_init, norm_init,
                     softmax_cross_entropy, with_logical_constraint)
from .lm import _scan_stack

MAX_DEC_POS = 32_768


def init_params(cfg, key) -> Tuple[Params, Dict]:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.d_model

    def stack(k, kind, count):
        def one(kk):
            bp, _ = block_init(cfg, kk, dtype, kind)
            return {"b0": bp}
        _, bx = block_init(cfg, jax.random.PRNGKey(0), dtype, kind)
        ax = {"b0": jax.tree.map(lambda t: ("layers",) + tuple(t), bx,
                                 is_leaf=lambda t: isinstance(t, tuple))}
        return jax.vmap(one)(jax.random.split(k, count)), ax

    enc, enc_ax = stack(ks[0], "encoder", cfg.n_enc_layers)
    dec, dec_ax = stack(ks[1], "decoder", cfg.n_layers)
    p = {
        "embed": embed_init(ks[2], cfg.padded_vocab, d, dtype),
        "pos_enc": (jax.random.normal(ks[3], (cfg.enc_seq, d), jnp.float32)
                    * 0.02).astype(dtype),
        "pos_dec": (jax.random.normal(ks[4], (MAX_DEC_POS, d), jnp.float32)
                    * 0.02).astype(dtype),
        "enc_stack": enc,
        "dec_stack": dec,
    }
    ax = {
        "embed": ("vocab", "embed"),
        "pos_enc": (None, "embed"),
        "pos_dec": (None, "embed"),
        "enc_stack": enc_ax,
        "dec_stack": dec_ax,
    }
    p["enc_norm"], ax["enc_norm"] = norm_init(cfg, d, dtype)
    p["final_norm"], ax["final_norm"] = norm_init(cfg, d, dtype)
    p["lm_head"] = embed_init(ks[5], cfg.padded_vocab, d, dtype).T
    ax["lm_head"] = ("embed", "vocab")
    return p, ax


def encode(cfg, p: Params, frames: jnp.ndarray) -> jnp.ndarray:
    x = frames.astype(p["pos_enc"].dtype) + p["pos_enc"][None]
    x = with_logical_constraint(x, "batch", None, None)
    x, _, _ = _scan_stack(cfg, p["enc_stack"], x, ("encoder",))
    return apply_norm(cfg, x, p["enc_norm"])


def forward(cfg, p: Params, batch: Dict[str, jnp.ndarray], *,
            collect_cache: bool = False):
    enc_out = encode(cfg, p, batch["frames"])
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = jnp.take(p["embed"], tokens, axis=0) + p["pos_dec"][None, :S]
    x = with_logical_constraint(x, "batch", None, None)
    x, ys, aux = _scan_stack(cfg, p["dec_stack"], x, ("decoder",),
                             collect_cache=collect_cache, enc_out=enc_out)
    x = apply_norm(cfg, x, p["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    logits = with_logical_constraint(logits, "batch", None, "vocab_act")
    return logits, ([ys] if collect_cache else None), aux


def loss_fn(cfg, p: Params, batch: Dict[str, jnp.ndarray]):
    logits, _, _ = forward(cfg, p, batch)
    ce = softmax_cross_entropy(logits[:, :-1, :], batch["tokens"][:, 1:],
                               cfg.vocab_size)
    loss = jnp.mean(ce)
    return loss, {"loss": loss, "ce": loss}


def init_cache(cfg, batch: int, max_seq: int) -> List[Any]:
    dtype = dtype_of(cfg.param_dtype)
    KV, hd = cfg.n_kv_heads, cfg.head_dim_

    def one(_):
        c = init_block_cache(cfg, "decoder", batch, max_seq, dtype)
        c["cross_k"] = jnp.zeros((batch, cfg.enc_seq, KV, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, cfg.enc_seq, KV, hd), dtype)
        return {"b0": c}

    return [jax.vmap(one)(jnp.arange(cfg.n_layers))]


def decode_step(cfg, p: Params, caches: List[Any], token: jnp.ndarray,
                pos: jnp.ndarray):
    pe = jax.lax.dynamic_slice_in_dim(p["pos_dec"], pos.astype(jnp.int32),
                                      1, axis=0)            # (1, d)
    x = jnp.take(p["embed"], token, axis=0) + pe[None]       # (B, 1, d)
    x = with_logical_constraint(x, "batch", None, None)
    x, ys, _ = _scan_stack(cfg, p["dec_stack"], x, ("decoder",),
                           caches=caches[0], cache_pos=pos)
    x = apply_norm(cfg, x, p["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    return logits, [ys]


def prefill(cfg, p: Params, batch: Dict[str, jnp.ndarray]):
    logits, caches, _ = forward(cfg, p, batch, collect_cache=True)
    return logits[:, -1:, :], caches
