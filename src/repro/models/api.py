"""Uniform model API: one bundle per architecture config.

``build(cfg)`` returns a :class:`ModelBundle` whose members are plain
functions — the launcher, trainer, server, dry-run and tests all consume
this one interface:

    init(key)                      -> params
    loss(params, batch)            -> (scalar, metrics)
    forward(params, batch)         -> logits
    prefill(params, batch)         -> (last_logits, caches)
    decode(params, caches, token, pos) -> (logits, caches)
    init_cache(batch, max_seq)     -> caches
    param_logical_axes()           -> pytree of logical-axis tuples
    input_specs(shape, kind)       -> ShapeDtypeStruct batch for .lower()

``input_specs`` is the multi-pod dry-run's entry point: weak-type-correct,
shardable stand-ins, no device allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import lm, whisper
from .common import dtype_of
from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    forward: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    param_logical_axes: Callable
    input_specs: Callable


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM frontends consume part of the sequence budget."""
    if cfg.family == "vlm":
        return seq_len - cfg.frontend_seq
    return seq_len


def _batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.param_dtype)
    if cfg.family == "encdec":
        return {
            "frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.frontend_dim), dt),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "tokens": jax.ShapeDtypeStruct((B, _text_len(cfg, S)), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq, cfg.frontend_dim), dt),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def build(cfg: ModelConfig) -> ModelBundle:
    mod = whisper if cfg.family == "encdec" else lm

    def init(key):
        p, _ = mod.init_params(cfg, key)
        return p

    def param_logical_axes():
        cell = {}

        def f(k):
            p, ax = mod.init_params(cfg, k)
            cell["ax"] = ax  # static metadata; params never materialize
            return p

        jax.eval_shape(f, jax.random.PRNGKey(0))
        return cell["ax"]

    def loss(params, batch):
        return mod.loss_fn(cfg, params, batch)

    def forward(params, batch):
        out = mod.forward(cfg, params, batch)
        return out[0]

    def prefill(params, batch):
        return mod.prefill(cfg, params, batch)

    def decode(params, caches, token, pos):
        return mod.decode_step(cfg, params, caches, token, pos)

    def init_cache(batch, max_seq):
        return mod.init_cache(cfg, batch, max_seq)

    def input_specs(shape: ShapeSpec, kind: Optional[str] = None):
        kind = kind or shape.kind
        if kind in ("train", "prefill"):
            return _batch_specs(cfg, shape)
        # decode: one new token against a seq_len-deep cache
        B = shape.global_batch
        cache_specs = jax.eval_shape(lambda: init_cache(B, shape.seq_len))
        return {
            "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "caches": cache_specs,
        }

    return ModelBundle(cfg=cfg, init=init, loss=loss, forward=forward,
                       prefill=prefill, decode=decode, init_cache=init_cache,
                       param_logical_axes=param_logical_axes,
                       input_specs=input_specs)
