"""Mixture-of-Experts FFN with expert parallelism (EP) over the ``model``
mesh axis.

Dispatch is capacity-based (GShard-style, drop-on-overflow) but built for
pod scale: tokens stay sharded over (pod, data); experts are sharded over
``model``; the dispatch/return traffic is two explicit `all_to_all`s inside
a `shard_map` — exactly the "critical edge" traffic pattern the paper's
heterogeneous EdgeMatch penalizes for (§4.3), now as a first-class JAX
collective the roofline can see.

Covers both assigned MoE archs:
  * deepseek-moe-16b — 2 shared + 64 routed, top-6, fine-grained (d_ff 1408)
  * llama4-maverick  — 1 shared + 128 routed, top-1 (d_ff 8192)

The single-device path (no mesh) runs the same math with the all_to_alls
elided — that is the oracle the EP path is tested against.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .common import Params, dense_init, get_moe_ff_axis


def moe_init(cfg, key, dtype) -> Tuple[Params, Dict]:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": dense_init(ks[1], (E, d, f), dtype),
        "wu": dense_init(ks[2], (E, d, f), dtype),
        "wd": dense_init(ks[3], (E, f, d), dtype, in_axis=1),
    }
    ax = {
        "router": ("embed", None),
        "wg": ("expert", "embed", "moe_ff"),
        "wu": ("expert", "embed", "moe_ff"),
        "wd": ("expert", "moe_ff", "embed"),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared_wg"] = dense_init(ks[4], (d, fs), dtype)
        p["shared_wu"] = dense_init(ks[5], (d, fs), dtype)
        p["shared_wd"] = dense_init(ks[6], (fs, d), dtype, in_axis=0)
        ax["shared_wg"] = ("embed", "ff")
        ax["shared_wu"] = ("embed", "ff")
        ax["shared_wd"] = ("ff", "embed")
    return p, ax


def _expert_ffn(x, wg, wu, wd, ff_axis: Optional[str] = None):
    """x: (E_loc, C, d); weights (E_loc, d, f[/N])/(E_loc, f[/N], d).

    With ``ff_axis`` (TP/EP recipe) the hidden dim f is sharded over that
    mesh axis: the down-projection's partial sums reduce with a psum of the
    *activations* — expert weights never leave their shard.
    """
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    if ff_axis is not None:
        y = jax.lax.psum(y, ff_axis)
    return y


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = math.ceil(n_tokens * top_k / n_experts * factor)
    return max(8, c)


def _local_moe(cfg, x_flat, router_w, wg, wu, wd, *,
               model_size: int, model_axis: Optional[str],
               ff_axis: Optional[str] = None):
    """Per-device MoE over local tokens.  When ``model_axis`` is set, wg/wu/wd
    hold E/model_size local experts and dispatch crosses shards via
    all_to_all; otherwise all experts are local."""
    T, d = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (x_flat.astype(jnp.float32) @ router_w)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                         # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce)

    C = _capacity(T, k, E, cfg.capacity_factor)
    e_flat = idx.reshape(-1)                                     # (T*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                    # exclusive
    pos_flat = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos_flat < C
    pos_c = jnp.where(keep, pos_flat, 0)

    tok_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, C, d), x_flat.dtype)
    buf = buf.at[e_flat, pos_c].add(
        x_flat[tok_idx] * keep[:, None].astype(x_flat.dtype))

    if model_axis is not None and model_size > 1:
        # (E, C, d) -> (E/M, C*M, d): each shard receives its experts' slices
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
    out_buf = _expert_ffn(buf, wg, wu, wd, ff_axis=ff_axis)
    if model_axis is not None and model_size > 1:
        out_buf = jax.lax.all_to_all(out_buf, model_axis, split_axis=1,
                                     concat_axis=0, tiled=True)

    picked = out_buf[e_flat, pos_c]                              # (T*k, d)
    picked = picked * (keep[:, None] * gate.reshape(-1)[:, None]
                       ).astype(picked.dtype)
    y = picked.reshape(T, k, d).sum(axis=1)
    return y.astype(x_flat.dtype), aux


def moe_forward(cfg, p: Params, x: jnp.ndarray, *,
                mesh: Optional[Mesh] = None,
                data_spec: Tuple = ("data",),
                model_axis: str = "model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss).  Routed experts via EP shard_map when a
    mesh is provided; shared experts run as a plain TP-sharded dense FFN.
    """
    B, S, d = x.shape

    if mesh is not None and model_axis in mesh.axis_names and \
            mesh.shape[model_axis] > 1:
        M = mesh.shape[model_axis]
        # Split the sequence over the model axis too: each device dispatches a
        # DISTINCT token slice, so expert FLOPs are not replicated M times.
        # (Decode steps have S=1 — replicate there; the redundancy is one
        # token per device.)
        split_seq = S % M == 0
        dp = P(data_spec, model_axis if split_seq else None, None)
        ff_axis = get_moe_ff_axis()

        def body(xl, rw, wg, wu, wd):
            T = xl.shape[0] * xl.shape[1]
            y, aux = _local_moe(cfg, xl.reshape(T, d), rw, wg, wu, wd,
                                model_size=M, model_axis=model_axis,
                                ff_axis=ff_axis)
            # aux is per-device; average across the whole mesh
            aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
            return y.reshape(xl.shape), aux

        # expert weights: E over model; hidden dim optionally sharded over
        # ``ff_axis`` (the TP/EP recipe — no FSDP gathers at the boundary)
        wg_spec = P(model_axis, None, ff_axis)
        wd_spec = P(model_axis, ff_axis, None)
        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(dp, P(), wg_spec, wg_spec, wd_spec),
            out_specs=(dp, P()),
            check_rep=False,
        )(x, p["router"], p["wg"], p["wu"], p["wd"])
    else:
        y, aux = _local_moe(cfg, x.reshape(B * S, d), p["router"],
                            p["wg"], p["wu"], p["wd"],
                            model_size=1, model_axis=None)
        y = y.reshape(B, S, d)

    if cfg.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, p["shared_wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["shared_wu"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", h, p["shared_wd"])
    return y, aux
