"""Attention: chunked flash-style forward, GQA, sliding-window, cross,
and split-KV decode — pure JAX (the Pallas kernels in ``repro/kernels`` are
the TPU-optimized versions of the same math; the model uses these jnp paths
on the CPU dry-run backend).

Sharding strategy (see DESIGN.md):
  * projections — TP over the *fused* head dim (H*hd).  Head counts of the
    assigned archs rarely divide the 16-way model axis, but H*hd always does
    (hd is 64/128), so column/row parallelism is universally legal.
  * attention core (train/prefill) — query-sequence sharding over ``model``
    inside a shard_map: each shard ropes its local q/k at absolute
    positions, all-gathers K/V, and runs the chunked online-softmax locally.
    Works for any head count; attention FLOPs split 16-ways.
  * decode — split-KV: the cache's sequence dim is sharded over ``model``;
    partial softmax statistics combine exactly through jnp reductions, which
    GSPMD lowers to the matching collectives.  Per-device cache bytes drop
    by the model-axis size — this IS the roofline story for decode shapes.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .common import (Params, apply_rope, dense_init, get_mesh_context,
                     get_scan_unroll, rmsnorm)

NEG_INF = -1e30


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (>=1)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return max(c, 1)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attention_init(cfg, key, dtype, *, cross: bool = False
                   ) -> Tuple[Params, Dict]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype, in_axis=0),
    }
    ax = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
        ax["bq"] = ("heads",)
        ax["bk"] = ("kv_heads",)
        ax["bv"] = ("kv_heads",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return p, ax


def _project_qkv(cfg, p: Params, x: jnp.ndarray,
                 kv_x: Optional[jnp.ndarray] = None):
    """Returns q (B,Sq,H,hd), k/v (B,Skv,KV,hd) — un-roped."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*q.shape[:-1], H, hd)
    k = k.reshape(*k.shape[:-1], KV, hd)
    v = v.reshape(*v.shape[:-1], KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked flash-style attention (local math)
# ---------------------------------------------------------------------------

def _block_attn(q, k, v, mask, scale):
    """One (q-block, kv-block) tile of online-softmax attention.

    q: (B,cq,KV,G,hd)  k/v: (B,ck,KV,hd)  mask: (cq,ck) bool (True = keep)
    Returns fp32 (max, exp-sum, acc) for this block.
    """
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale         # (B,KV,G,cq,ck)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                               # (B,KV,G,cq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return m, l, acc


def chunked_attention(cfg, q, k, v, q_positions, kv_positions, *,
                      causal: bool, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 1024) -> jnp.ndarray:
    """Flash-style attention with online softmax over KV chunks.

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd).  Positions are absolute 1-D arrays.
    window>0 = sliding-window: banded gather, O(Sq*(window+chunk)) compute.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    cq = pick_chunk(Sq, q_chunk)
    qg = q.reshape(B, Sq, KV, G, hd)

    if window and window > 0:
        out = _banded_attention(qg, k, v, q_positions, kv_positions,
                                window=window, cq=cq, scale=scale)
        return out.reshape(B, Sq, H, hd).astype(q.dtype)

    ck = pick_chunk(Skv, kv_chunk)
    n_q, n_k = Sq // cq, Skv // ck

    def per_q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * cq, cq, axis=0)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, ki * ck, ck, axis=0)
            mask = (qp[:, None] >= kp[None, :]) if causal else \
                jnp.ones((cq, ck), bool)
            m, l, a = _block_attn(qb, kb, vb, mask, scale)
            m_new = jnp.maximum(m_run, m)
            r_old = jnp.exp(m_run - m_new)
            r_blk = jnp.exp(m - m_new)
            l_new = l_run * r_old + l * r_blk
            acc_new = acc * r_old[..., None] + a * r_blk[..., None]
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, cq), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, cq), jnp.float32),
                jnp.zeros((B, KV, G, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init, jnp.arange(n_k),
            unroll=True if get_scan_unroll() else 1)
        return acc / jnp.maximum(l, 1e-30)[..., None]      # (B,KV,G,cq,hd)

    _, outs = jax.lax.scan(lambda c, qi: (c, per_q_block(qi)), 0,
                           jnp.arange(n_q),
                           unroll=True if get_scan_unroll() else 1)
    out = jnp.moveaxis(outs, 0, 3)                          # (B,KV,G,n_q,cq,hd)
    out = out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _banded_attention(qg, k, v, q_positions, kv_positions, *,
                      window: int, cq: int, scale: float) -> jnp.ndarray:
    """Sliding-window attention: each q chunk attends a fixed-size KV band
    ``[chunk_start - window, chunk_end)`` — linear in sequence length.

    Assumes positions are contiguous and aligned between q and kv (the
    self-attention case; SWA cross-attention is not a thing we need).
    """
    B, Sq, KV, G, hd = qg.shape
    band = window + cq
    n_q = Sq // cq
    pad = window
    kpad = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    kp_pad = jnp.pad(kv_positions, (pad, 0), constant_values=-1)

    def per_q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * cq, cq, axis=0)
        # band = [g0 - window, g0 + cq) in *global* kv coords, where g0 is the
        # chunk's absolute start (q may be a sequence shard); kpad's front
        # padding of `window` makes the padded slice start exactly g0.
        start = qp[0]
        kb = jax.lax.dynamic_slice_in_dim(kpad, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vpad, start, band, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kp_pad, start, band, axis=0)
        mask = (qp[:, None] >= kp[None, :]) & \
               (qp[:, None] - kp[None, :] < window) & (kp[None, :] >= 0)
        m, l, a = _block_attn(qb, kb, vb, mask, scale)
        return a / jnp.maximum(l, 1e-30)[..., None]

    _, outs = jax.lax.scan(
        lambda c, qi: (c, jax.checkpoint(per_q_block)(qi)), 0,
        jnp.arange(n_q), unroll=True if get_scan_unroll() else 1)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, G, Sq, hd)
    return out.transpose(0, 3, 1, 2, 4)


def _flash_full(cfg, q, k, v, *, causal, window, use_rope, base_pos: int = 0):
    """Rope + chunked attention, sharded per ``cfg.attn_shard``:

      heads      — H and KV divide the model axis: each shard attends its
                   own heads over the full sequence.  Zero collectives (the
                   §Perf winner where legal — e.g. deepseek 16/16 heads).
      seq        — query-sequence shards + KV all-gather (legal for any head
                   count; the default for the assigned archs).
      replicated — no sharding of the attention core (model-axis devices
                   repeat it).  Only sensible when attention is a small
                   fraction of the step and the gathers dominate.
      auto       — heads if divisible, else seq if S divides, else replicated.

    q/k/v are un-roped projections, (B,S,*,hd).  Returns (y, k_roped, v).
    """
    mesh, data_spec, model_axis = get_mesh_context()
    B, S = q.shape[0], q.shape[1]

    def local(q_l, k_l, v_l, shard_idx, n_shards):
        Sl = q_l.shape[1]
        qpos = base_pos + shard_idx * Sl + jnp.arange(Sl)
        if use_rope:
            q_r = apply_rope(q_l, qpos, cfg.rope_theta)
            k_r = apply_rope(k_l, qpos, cfg.rope_theta)
        else:
            q_r, k_r = q_l, k_l
        if n_shards > 1:
            k_full = jax.lax.all_gather(k_r, model_axis, axis=1, tiled=True)
            v_full = jax.lax.all_gather(v_l, model_axis, axis=1, tiled=True)
        else:
            k_full, v_full = k_r, v_l
        kpos = base_pos + jnp.arange(k_full.shape[1])
        y = chunked_attention(cfg, q_r, k_full, v_full, qpos, kpos,
                              causal=causal, window=window)
        return y, k_r, v_l

    if mesh is not None and model_axis in mesh.axis_names:
        M = mesh.shape[model_axis]
        mode = cfg.attn_shard
        if mode == "auto":
            # baseline (paper-faithful) default: sequence sharding; "heads"
            # is the explicit §Perf opt-in where head counts divide the mesh
            if M > 1 and S % M == 0:
                mode = "seq"
            elif M > 1 and cfg.n_heads % M == 0 and cfg.n_kv_heads % M == 0:
                mode = "heads"
            else:
                mode = "replicated"
        if mode == "heads" and M > 1 and cfg.n_heads % M == 0 and                 cfg.n_kv_heads % M == 0:
            dq = P(data_spec, None, model_axis, None)

            def body_h(q_l, k_l, v_l):
                qpos = base_pos + jnp.arange(S)
                if use_rope:
                    q_r = apply_rope(q_l, qpos, cfg.rope_theta)
                    k_r = apply_rope(k_l, qpos, cfg.rope_theta)
                else:
                    q_r, k_r = q_l, k_l
                y = chunked_attention(cfg, q_r, k_r, v_l, qpos, qpos,
                                      causal=causal, window=window)
                return y, k_r, v_l

            return shard_map(body_h, mesh=mesh, in_specs=(dq, dq, dq),
                             out_specs=(dq, dq, dq), check_rep=False
                             )(q, k, v)
        if mode == "seq" and M > 1 and S % M == 0:
            dp = P(data_spec, model_axis, None, None)

            def body(q_l, k_l, v_l):
                i = jax.lax.axis_index(model_axis)
                return local(q_l, k_l, v_l, i, M)

            return shard_map(body, mesh=mesh, in_specs=(dp, dp, dp),
                             out_specs=(dp, dp, dp), check_rep=False)(q, k, v)
    return local(q, k, v, 0, 1)


# ---------------------------------------------------------------------------
# decode (split-KV) + cache plumbing
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, dtype) -> Dict[str, jnp.ndarray]:
    """Sliding-window archs keep only a ring buffer of ``window`` entries."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    return {
        "k": jnp.zeros((batch, S, KV, hd), dtype),
        "v": jnp.zeros((batch, S, KV, hd), dtype),
    }


def update_cache(cfg, cache: Dict[str, jnp.ndarray], k_new, v_new,
                 pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write one token's K/V at ``pos`` (ring-indexed under sliding window).

    One-hot select keeps the sequence dim shardable (split-KV decode);
    k_new/v_new: (B,1,KV,hd).
    """
    S = cache["k"].shape[1]
    slot = pos % S if cfg.sliding_window else pos
    iota = jnp.arange(S)
    hit = (iota == slot)[None, :, None, None]
    return {
        "k": jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"]),
        "v": jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"]),
    }


def decode_attention(cfg, q, cache: Dict[str, jnp.ndarray],
                     pos: jnp.ndarray) -> jnp.ndarray:
    """Single-token attention over the (possibly seq-sharded) cache.

    q: (B,1,H,hd) -> (B,1,H,hd).  Exact softmax even when the cache's seq dim
    is sharded: the reductions lower to psum-style collectives under GSPMD.
    """
    B, _, H, hd = q.shape
    k, v = cache["k"], cache["v"]
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale          # (B,KV,G,S)
    iota = jnp.arange(S)
    if cfg.sliding_window:
        # ring slot i holds absolute position p_i = i + floor((pos-i)/S)*S
        wrap = (pos - iota) // S
        abs_pos = iota + wrap * S
        valid = (abs_pos >= 0) & (abs_pos <= pos) & \
                (pos - abs_pos < cfg.sliding_window)
    else:
        valid = iota <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", p / jnp.maximum(l, 1e-30),
                     v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# full module: project -> rope -> attend -> out-proj
# ---------------------------------------------------------------------------

def attention_forward(cfg, p: Params, x: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      use_rope: bool = True,
                      kv_x: Optional[jnp.ndarray] = None,
                      cache: Optional[Dict] = None,
                      cache_pos: Optional[jnp.ndarray] = None,
                      precomputed_kv: Optional[Tuple] = None):
    """Unified attention module.

    * train/prefill (cache=None): chunked flash attention; returns
      (y, (k_roped, v)) so prefill can seed the decode cache.
    * decode (cache given, x is (B,1,d)): split-KV decode; returns
      (y, new_cache).
    * cross-attention: pass precomputed_kv=(k, v) from the encoder; with a
      cache dict containing them, decode just reads.
    """
    H, hd = cfg.n_heads, cfg.head_dim_

    if precomputed_kv is not None:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
            *x.shape[:-1], H, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k, v = precomputed_kv
        if x.shape[1] == 1:  # cross-attention decode: plain gathered attend
            y = decode_attention(cfg, q, {"k": k, "v": v},
                                 jnp.asarray(k.shape[1] - 1))
        else:
            qpos = jnp.arange(x.shape[1])
            kpos = jnp.arange(k.shape[1])
            y = chunked_attention(cfg, q, k, v, qpos, kpos, causal=False)
        y = jnp.einsum("bsh,hd->bsd", y.reshape(*y.shape[:-2], H * hd),
                       p["wo"])
        return y, None

    q, k, v = _project_qkv(cfg, p, x, kv_x)

    if kv_x is not None and cache is None:
        # cross-attention, full mode (whisper decoder): no rope, not causal,
        # q/kv lengths differ -> direct chunked attention
        qpos = jnp.arange(x.shape[1])
        kpos = jnp.arange(kv_x.shape[1])
        y = chunked_attention(cfg, q, k, v, qpos, kpos, causal=False)
        y = jnp.einsum("bsh,hd->bsd", y.reshape(*y.shape[:-2], H * hd),
                       p["wo"])
        return y, (k, v)

    if cache is not None:
        if use_rope:
            q = apply_rope(q, cache_pos[None], cfg.rope_theta)
            k = apply_rope(k, cache_pos[None], cfg.rope_theta)
        new_cache = update_cache(cfg, cache, k, v, cache_pos)
        y = decode_attention(cfg, q, new_cache, cache_pos)
        y = jnp.einsum("bsh,hd->bsd", y.reshape(*y.shape[:-2], H * hd),
                       p["wo"])
        return y, new_cache

    y, k_r, v_r = _flash_full(cfg, q, k, v, causal=causal, window=window,
                              use_rope=use_rope)
    y = jnp.einsum("bsh,hd->bsd", y.reshape(*y.shape[:-2], H * hd), p["wo"])
    return y, (k_r, v_r)
