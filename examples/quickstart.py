"""Quickstart: the paper's headline scenario, end to end, on CPU.

1. Build a 6x6 inter-core-connected NPU ("pod") over host devices.
2. Ask the hypervisor for two tenants whose topologies could never coexist
   under fixed MIG partitions — the similar-topology mapper places both
   (the paper's anti-lock-in result).
3. Run a real (reduced) model inside each tenant's JAX mesh.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core import (DeviceTopology, Hypervisor, allocate_tenant, mesh_2d)
from repro.models import build
from repro.models.common import clear_mesh_context


def main():
    devs = jax.devices()[:8]
    dt = DeviceTopology.from_devices(devs, (2, 4))
    hyp = Hypervisor(dt.topo, hbm_bytes=1 << 32)
    print(f"physical NPU: 2x4 mesh over {len(devs)} devices")

    # two 1x4 tenants — a fixed half/half MIG split could also do this, but
    # try 2x2 + 1x4 + irregular leftovers and MIG breaks; the mapper doesn't
    t1 = allocate_tenant(hyp, dt, mesh_2d(2, 2, base_id=100),
                         axis_names=("data", "model"))
    t2 = allocate_tenant(hyp, dt, mesh_2d(1, 4, base_id=200),
                         axis_names=("data", "model"))
    print(f"tenant1 cores={sorted(t1.vnpu.p_cores)} exact={t1.vnpu.exact} "
          f"ted={t1.vnpu.ted}")
    print(f"tenant2 cores={sorted(t2.vnpu.p_cores)} exact={t2.vnpu.exact} "
          f"ted={t2.vnpu.ted}")
    print(f"utilization: {hyp.utilization():.0%}")

    # run a reduced llama inside tenant1's mesh
    cfg = reduce_for_smoke(get_config("llama3_2_1b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size - 1)}
    with t1.mesh:
        loss, metrics = jax.jit(bundle.loss)(params, batch)
    print(f"tenant1 ran {cfg.name} forward+loss on its submesh: "
          f"loss={float(loss):.3f}")
    clear_mesh_context()
    print("OK")


if __name__ == "__main__":
    main()
