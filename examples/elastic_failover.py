"""Elastic failover drill through the cluster placement API:
train -> checkpoint -> 'device failure' -> policy-driven live migration
(similar-topology remap avoiding the dead core) -> restore on the new
submesh -> keep training -> 'device repaired' -> capacity returns to the
free pool.

The paper's topology mapper is the failover mechanism: ``VNPUPolicy.migrate``
re-runs minTopologyEditDistance over the survivors (the same call the
cluster scheduler uses for defragmentation — failure is just a migration
with a forbidden core) and the checkpoint reshards onto whatever submesh
came back.  The pause charged in the cluster simulator is exactly what this
drill performs for real: routing-table reinstall + weight re-warm from the
checkpoint, with the RTT (global memory) preserved.

Run: PYTHONPATH=src python examples/elastic_failover.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core import DeviceTopology
from repro.core import simulator as S
from repro.core.vmesh import virtual_mesh
from repro.data import DataConfig, make_batch
from repro.models import build
from repro.sched import TenantSpec, VNPUPolicy
from repro.train import AdamWConfig, TrainConfig, init_state, make_train_step


def main():
    devs = jax.devices()[:8]
    dt = DeviceTopology.from_devices(devs, (2, 4))
    policy = VNPUPolicy(dt.topo, hbm_bytes=1 << 32)
    spec = TenantSpec(tid=1, model="qwen2_0_5b", n_cores=4, arrival_s=0.0,
                      duration_s=600.0)
    placement = policy.allocate(spec)
    mesh = virtual_mesh(placement.vnpu, dt)
    print(f"tenant on cores {list(placement.cores)}")

    cfg = reduce_for_smoke(get_config("qwen2_0_5b"))
    bundle = build(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2))
    step = jax.jit(make_train_step(bundle.loss, tcfg))
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg.opt)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

    def batch_at(i):
        return {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}

    with mesh:
        for i in range(3):
            state, m = step(state, batch_at(i))
    print(f"trained 3 steps, loss={float(m['loss']):.3f}")

    ckpt = tempfile.mkdtemp(prefix="elastic-")
    save_checkpoint(ckpt, state, step=3)
    print(f"checkpointed at step 3 -> {ckpt}")

    # ---- simulated failure of one allocated device --------------------
    dead = placement.cores[0]
    print(f"!! device at core {dead} failed")
    policy.mark_failed([dead])       # quarantine: never reallocated
    placement, moved = policy.migrate(placement, avoid=[dead])
    assert moved and dead not in placement.cores
    assert dead not in policy.free_cores()
    pause = policy.migration_cycles(placement, 64 << 20,
                                    S.SIM_CONFIG.hbm_bytes_per_cycle)
    print(f"migrated: new cores {list(placement.cores)} "
          f"(ted={placement.vnpu.ted}, modeled pause "
          f"{pause / S.SIM_CONFIG.freq_hz * 1e3:.2f} ms)")
    mesh = virtual_mesh(placement.vnpu, dt)

    like = jax.eval_shape(lambda: init_state(
        bundle.init(jax.random.PRNGKey(0)), tcfg.opt))
    state, start = restore_checkpoint(ckpt, like)
    print(f"restored step {start} onto the new submesh")
    with mesh:
        for i in range(start, start + 2):
            state, m = step(state, batch_at(i))
    print(f"resumed training, step={int(state['step'])}, "
          f"loss={float(m['loss']):.3f}")

    # ---- the device comes back from maintenance -----------------------
    # repair is the other half of the chaos plane: the quarantined core
    # rejoins the free pool (the scheduler's REPAIR event drives this
    # same call and then drains its admission queue)
    policy.mark_repaired([dead])
    assert dead in policy.free_cores()
    spare = policy.allocate(TenantSpec(tid=2, model="qwen2_0_5b",
                                       n_cores=4, arrival_s=0.0,
                                       duration_s=60.0))
    print(f"core {dead} repaired; new tenant placed on "
          f"{list(spare.cores)} using the restored capacity")
    policy.release(spare)
    print("OK")


if __name__ == "__main__":
    main()
