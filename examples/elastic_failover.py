"""Elastic failover drill: train -> checkpoint -> 'device failure' ->
similar-topology remap -> restore on the new submesh -> keep training.

The paper's topology mapper is the failover mechanism: on failure the
hypervisor re-runs minTopologyEditDistance over the survivors and the
checkpoint reshards onto whatever submesh came back.

Run: PYTHONPATH=src python examples/elastic_failover.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core import DeviceTopology, Hypervisor, allocate_tenant, \
    elastic_remap, mesh_2d
from repro.data import DataConfig, make_batch
from repro.models import build
from repro.train import AdamWConfig, TrainConfig, init_state, make_train_step


def main():
    devs = jax.devices()[:8]
    dt = DeviceTopology.from_devices(devs, (2, 4))
    hyp = Hypervisor(dt.topo, hbm_bytes=1 << 32)
    tenant = allocate_tenant(hyp, dt, mesh_2d(2, 2, base_id=100))
    print(f"tenant on cores {sorted(tenant.vnpu.p_cores)}")

    cfg = reduce_for_smoke(get_config("qwen2_0_5b"))
    bundle = build(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2))
    step = jax.jit(make_train_step(bundle.loss, tcfg))
    state = init_state(bundle.init(jax.random.PRNGKey(0)), tcfg.opt)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

    def batch_at(i):
        return {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}

    with tenant.mesh:
        for i in range(3):
            state, m = step(state, batch_at(i))
    print(f"trained 3 steps, loss={float(m['loss']):.3f}")

    ckpt = tempfile.mkdtemp(prefix="elastic-")
    save_checkpoint(ckpt, state, step=3)
    print(f"checkpointed at step 3 -> {ckpt}")

    # ---- simulated failure of one allocated device --------------------
    dead = next(iter(tenant.vnpu.p_cores))
    print(f"!! device at core {dead} failed")
    tenant = elastic_remap(hyp, dt, tenant, [dead])
    print(f"remapped: new cores {sorted(tenant.vnpu.p_cores)} "
          f"(ted={tenant.vnpu.ted})")

    like = jax.eval_shape(lambda: init_state(
        bundle.init(jax.random.PRNGKey(0)), tcfg.opt))
    state, start = restore_checkpoint(ckpt, like)
    print(f"restored step {start} onto the new submesh")
    with tenant.mesh:
        for i in range(start, start + 2):
            state, m = step(state, batch_at(i))
    print(f"resumed training, step={int(state['step'])}, "
          f"loss={float(m['loss']):.3f}")
    print("OK")


if __name__ == "__main__":
    main()
