"""End-to-end training driver: a ~100M-parameter llama-family model for a
few hundred steps on the synthetic-but-learnable pipeline, with periodic
checkpointing and straggler detection — deliverable (b)'s training example.

CPU note: a true 100M/300-step run takes hours on this container; the
default invocation trains a ~14M model for 60 steps (same code path, every
subsystem exercised).  Pass --full for the real thing on real hardware.

Run: PYTHONPATH=src python examples/train_100m.py [--full]
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import DataConfig, make_batch
from repro.models import build
from repro.train import AdamWConfig, TrainConfig, train_loop


def small_llama(full: bool) -> ModelConfig:
    if full:
        # ~100M params
        return ModelConfig(name="llama_100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4,
                           d_ff=2048, vocab_size=32000, head_dim=64)
    return ModelConfig(name="llama_14m", family="dense", n_layers=4,
                       d_model=256, n_heads=4, n_kv_heads=2,
                       d_ff=688, vocab_size=8192, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    cfg = small_llama(args.full)
    steps = args.steps or (300 if args.full else 60)

    bundle = build(cfg)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {steps} steps")
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=256 if args.full
                      else 128, global_batch=8)

    def it():
        s = 0
        while True:
            yield {k: jnp.asarray(v) for k, v in make_batch(dcfg, s).items()}
            s += 1

    ckpt = tempfile.mkdtemp(prefix="train100m-")
    stragglers = []
    state, hist = train_loop(
        bundle, tcfg, it(), n_steps=steps, key=jax.random.PRNGKey(0),
        checkpoint_dir=ckpt, checkpoint_every=max(steps // 3, 10),
        on_straggler=stragglers.append, log_every=max(steps // 10, 1))
    print("loss curve:", [round(h["loss"], 3) for h in hist])
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print(f"checkpoints in {ckpt}; stragglers flagged: {stragglers}")
    print("OK")


if __name__ == "__main__":
    main()
