"""Multi-tenant serving through the cluster placement API: two different
architectures served concurrently from one physical NPU, each admitted as a
tenant via ``VNPUPolicy`` (the paper's hypervisor behind the scheduler's
``PlacementPolicy`` protocol), materialized as its own JAX submesh, with
QoS bandwidth caps — the paper's cloud scenario (§2.2/§6.3) as a running
system.

The same placement objects also feed the analytic simulator: each tenant
is scored against the NoC flows its *actual co-resident* injects, the
wiring the event-driven cluster scheduler (benchmarks/cluster_sim.py) uses
at scale.

Run: PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core import DeviceTopology
from repro.core import simulator as S
from repro.core import workloads as W
from repro.core.vmesh import virtual_mesh
from repro.models import build
from repro.models.common import clear_mesh_context
from repro.sched import TenantSpec, VNPUPolicy
from repro.serve import EngineConfig, ServeEngine


def main():
    devs = jax.devices()[:8]
    dt = DeviceTopology.from_devices(devs, (2, 4))
    policy = VNPUPolicy(dt.topo, hbm_bytes=1 << 32)

    tenants = {}
    for tid, (name, arch) in enumerate((("tenant-llama", "llama3_2_1b"),
                                        ("tenant-qwen", "qwen2_0_5b")), 1):
        spec = TenantSpec(tid=tid, model=arch, n_cores=4, arrival_s=0.0,
                          duration_s=60.0, memory_bytes=64 << 20,
                          bandwidth_cap=1 << 28)
        placement = policy.allocate(spec)
        mesh = virtual_mesh(placement.vnpu, dt)
        cfg = reduce_for_smoke(get_config(arch))
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(hash(name) % 2**31))
        engine = ServeEngine(bundle, params,
                             EngineConfig(batch_size=2, max_seq=64))
        tenants[name] = (placement, mesh, engine, cfg)
        print(f"{name}: arch={arch} cores={list(placement.cores)} "
              f"bw_cap={placement.vnpu.access_counter.max} B/window")
    print(f"utilization: {policy.utilization():.0%}")

    # the scheduler's view: each tenant scored against its co-resident's
    # actual NoC flows (nothing hand-set)
    hw = S.SIM_CONFIG
    proxy = W.transformer_generic(seq=64)
    flows = {n: S.tenant_flows(proxy, p.cores, dt.topo, hw, owner=p.tid)
             for n, (p, _, _, _) in tenants.items()}
    for name, (p, _, _, _) in tenants.items():
        external = [f for o, fs in flows.items() if o != name for f in fs]
        rep = S.simulate(proxy, list(p.cores), dt.topo, hw,
                         external_flows=external)
        print(f"{name}: simulated {rep.mode} interval="
              f"{rep.interval_cycles} cyc ({rep.fps:.0f} it/s shared mesh)")

    rng = np.random.default_rng(0)
    for name, (placement, mesh, engine, cfg) in tenants.items():
        for _ in range(2):
            engine.submit(rng.integers(0, cfg.vocab_size - 1, size=8)
                          .astype(np.int32), max_new_tokens=4)
        with mesh:
            reqs = engine.run()
        clear_mesh_context()
        print(f"{name}: {[r.out_tokens for r in reqs]}  stats={engine.stats}")

    for name, (placement, _, _, _) in tenants.items():
        policy.release(placement)
    print(f"after release: utilization {policy.utilization():.0%}")
    print("OK")


if __name__ == "__main__":
    main()
