"""Multi-tenant serving: two different architectures served concurrently
from one physical NPU, each in its own vNPU submesh with QoS bandwidth caps
— the paper's cloud scenario (§2.2/§6.3) as a running system.

Run: PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core import DeviceTopology, Hypervisor, VNPURequest, \
    allocate_tenant, mesh_2d
from repro.models import build
from repro.serve import EngineConfig, ServeEngine
from repro.models.common import clear_mesh_context


def main():
    devs = jax.devices()[:8]
    dt = DeviceTopology.from_devices(devs, (2, 4))
    hyp = Hypervisor(dt.topo, hbm_bytes=1 << 32)

    tenants = {}
    for name, arch in (("tenant-llama", "llama3_2_1b"),
                       ("tenant-qwen", "qwen2_0_5b")):
        t = allocate_tenant(hyp, dt, mesh_2d(2, 2, base_id=100),
                            memory_bytes=64 << 20,
                            bandwidth_cap=1 << 28)
        cfg = reduce_for_smoke(get_config(arch))
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(hash(name) % 2**31))
        engine = ServeEngine(bundle, params,
                             EngineConfig(batch_size=2, max_seq=64))
        tenants[name] = (t, engine, cfg)
        print(f"{name}: arch={arch} cores={sorted(t.vnpu.p_cores)} "
              f"bw_cap={t.vnpu.access_counter.max} B/window")
    print(f"utilization: {hyp.utilization():.0%}")

    rng = np.random.default_rng(0)
    for name, (t, engine, cfg) in tenants.items():
        for _ in range(2):
            engine.submit(rng.integers(0, cfg.vocab_size - 1, size=8)
                          .astype(np.int32), max_new_tokens=4)
        with t.mesh:
            reqs = engine.run()
        clear_mesh_context()
        print(f"{name}: {[r.out_tokens for r in reqs]}  stats={engine.stats}")
    print("OK")


if __name__ == "__main__":
    main()
